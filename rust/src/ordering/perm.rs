//! Permutation type shared by ordering, symbolic and solve phases.

/// A permutation of `0..n`, stored as `perm[old] = new`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Permutation {
    perm: Vec<usize>,
}

impl Permutation {
    /// Identity permutation of length `n`.
    pub fn identity(n: usize) -> Self {
        Self { perm: (0..n).collect() }
    }

    /// From an `old → new` map. Panics if not a permutation.
    pub fn from_vec(perm: Vec<usize>) -> Self {
        let p = Self { perm };
        assert!(p.is_valid(), "not a permutation");
        p
    }

    /// From a *new → old* order (list of old indices in new order),
    /// e.g. an elimination order.
    pub fn from_order(order: &[usize]) -> Self {
        let mut perm = vec![usize::MAX; order.len()];
        for (new, &old) in order.iter().enumerate() {
            perm[old] = new;
        }
        Self::from_vec(perm)
    }

    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// `old → new` slice.
    pub fn as_slice(&self) -> &[usize] {
        &self.perm
    }

    /// New index of `old`.
    pub fn apply(&self, old: usize) -> usize {
        self.perm[old]
    }

    /// Inverse permutation (`new → old`).
    pub fn inverse(&self) -> Permutation {
        let mut inv = vec![0usize; self.perm.len()];
        for (old, &new) in self.perm.iter().enumerate() {
            inv[new] = old;
        }
        Permutation { perm: inv }
    }

    /// Validity: bijection on `0..n`.
    pub fn is_valid(&self) -> bool {
        let n = self.perm.len();
        let mut seen = vec![false; n];
        for &p in &self.perm {
            if p >= n || seen[p] {
                return false;
            }
            seen[p] = true;
        }
        true
    }

    /// Permute a vector: `out[perm[i]] = v[i]`.
    pub fn permute_vec<T: Clone>(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.perm.len());
        let mut out = v.to_vec();
        for (old, &new) in self.perm.iter().enumerate() {
            out[new] = v[old].clone();
        }
        out
    }

    /// Composition: apply `self` then `other` (`(other ∘ self)[i] = other[self[i]]`).
    pub fn then(&self, other: &Permutation) -> Permutation {
        assert_eq!(self.len(), other.len());
        Permutation {
            perm: self.perm.iter().map(|&p| other.perm[p]).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn inverse_round_trips() {
        let p = Permutation::from_vec(vec![2, 0, 1, 3]);
        let inv = p.inverse();
        for i in 0..4 {
            assert_eq!(inv.apply(p.apply(i)), i);
        }
    }

    #[test]
    fn from_order_builds_old_to_new() {
        // elimination order: first 2, then 0, then 1
        let p = Permutation::from_order(&[2, 0, 1]);
        assert_eq!(p.apply(2), 0);
        assert_eq!(p.apply(0), 1);
        assert_eq!(p.apply(1), 2);
    }

    #[test]
    #[should_panic]
    fn from_vec_rejects_duplicates() {
        Permutation::from_vec(vec![0, 0, 1]);
    }

    #[test]
    fn permute_vec_places_elements() {
        let p = Permutation::from_vec(vec![1, 2, 0]);
        let v = p.permute_vec(&[10, 20, 30]);
        assert_eq!(v, vec![30, 10, 20]);
    }

    #[test]
    fn composition_applies_in_order() {
        let p = Permutation::from_vec(vec![1, 0, 2]);
        let q = Permutation::from_vec(vec![2, 1, 0]);
        let c = p.then(&q);
        for i in 0..3 {
            assert_eq!(c.apply(i), q.apply(p.apply(i)));
        }
    }

    #[test]
    fn identity_is_valid_and_noop() {
        let p = Permutation::identity(5);
        assert!(p.is_valid());
        assert_eq!(p.permute_vec(&[1, 2, 3, 4, 5]), vec![1, 2, 3, 4, 5]);
    }
}
