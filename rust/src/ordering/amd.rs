//! Quotient-graph minimum-degree ordering with AMD-style approximate
//! external degrees.
//!
//! This is the fill-reducing workhorse of the pipeline (the paper's phase 1;
//! SuperLU/PanguLU use METIS or (A)MD). We implement the element/variable
//! quotient-graph formulation of Amestoy–Davis–Duff:
//!
//! * eliminating variable `p` turns it into an *element* whose variable set
//!   `L_p` is `adj_var(p) ∪ (∪_{e ∈ adj_el(p)} vars(e)) \ {p}`;
//! * all elements adjacent to `p` are absorbed into the new element;
//! * for every `i ∈ L_p`, the variable adjacency is pruned of members of
//!   `L_p` (they are now reachable through the element), and the degree is
//!   recomputed approximately as `|adj_var(i)| + Σ_e |vars(e) \ {i}|`.
//!
//! Degrees are kept in a lazy binary heap (no decrease-key; stale entries
//! are skipped on pop), which keeps the implementation compact while
//! retaining the O((n+m) log n)-ish practical behaviour needed for the
//! benchmark suite.

use super::Permutation;
use crate::sparse::Csc;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Approximate-minimum-degree ordering of the symmetric pattern `m`
/// (pass `a.plus_transpose_pattern()`). Returns `old → new`.
pub fn min_degree(m: &Csc) -> Permutation {
    let n = m.n_cols();
    if n == 0 {
        return Permutation::identity(0);
    }

    // Variable adjacency (no self loops) and element bookkeeping.
    let mut adj_var: Vec<Vec<usize>> = (0..n)
        .map(|j| m.col_rows(j).iter().copied().filter(|&i| i != j).collect())
        .collect();
    let mut adj_el: Vec<Vec<usize>> = vec![Vec::new(); n];
    // element id == eliminated variable id; vars(e) stored here
    let mut el_vars: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut eliminated = vec![false; n];
    let mut absorbed = vec![false; n]; // for elements
    let mut degree: Vec<usize> = adj_var.iter().map(|a| a.len()).collect();

    let mut heap: BinaryHeap<Reverse<(usize, usize)>> = BinaryHeap::with_capacity(2 * n);
    for v in 0..n {
        heap.push(Reverse((degree[v], v)));
    }

    let mut order = Vec::with_capacity(n);
    let mut mark = vec![0u32; n];
    let mut stamp = 0u32;

    while order.len() < n {
        // pop the true current-minimum (skip stale heap entries)
        let p = loop {
            let Reverse((d, v)) = heap.pop().expect("heap exhausted early");
            if !eliminated[v] && d == degree[v] {
                break v;
            }
        };
        eliminated[p] = true;
        order.push(p);

        // L_p := adj_var(p) ∪ ∪_{e} vars(e)  minus eliminated
        stamp += 1;
        mark[p] = stamp;
        let mut lp: Vec<usize> = Vec::new();
        for &v in &adj_var[p] {
            if !eliminated[v] && mark[v] != stamp {
                mark[v] = stamp;
                lp.push(v);
            }
        }
        for &e in &adj_el[p] {
            if absorbed[e] {
                continue;
            }
            for &v in &el_vars[e] {
                if !eliminated[v] && mark[v] != stamp {
                    mark[v] = stamp;
                    lp.push(v);
                }
            }
            absorbed[e] = true;
            el_vars[e].clear();
            el_vars[e].shrink_to_fit();
        }
        let absorbed_of_p: Vec<usize> = std::mem::take(&mut adj_el[p]);
        adj_var[p].clear();
        adj_var[p].shrink_to_fit();

        if lp.is_empty() {
            continue;
        }

        // new element keeps id p
        el_vars[p] = lp.clone();
        absorbed[p] = false;

        // update every variable in L_p
        for &i in &lp {
            // prune adj_var(i): drop eliminated vars and members of L_p
            // (mark[] still holds the L_p stamp; note mark[p] == stamp too)
            adj_var[i].retain(|&v| !eliminated[v] && mark[v] != stamp);
            // drop absorbed elements, add the new one
            adj_el[i].retain(|&e| !absorbed[e]);
            // avoid duplicate push of p if two paths (can't: retained list
            // had only live elements, p is new)
            adj_el[i].push(p);
            // approximate external degree
            let mut d = adj_var[i].len();
            for &e in &adj_el[i] {
                d += el_vars[e].len().saturating_sub(1);
            }
            let d = d.min(n - 1 - order.len().min(n - 1));
            if d != degree[i] {
                degree[i] = d;
                heap.push(Reverse((d, i)));
            } else {
                // degree unchanged but stored entry may be stale; repush is
                // harmless and keeps correctness simple
                heap.push(Reverse((d, i)));
            }
        }
        drop(absorbed_of_p);
    }

    Permutation::from_order(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;
    use crate::symbolic;

    fn fill_nnz(a: &Csc, p: &Permutation) -> usize {
        let pa = a.permute_sym(p.as_slice());
        let sym = symbolic::analyze(&pa);
        sym.nnz_ldu()
    }

    #[test]
    fn valid_permutation_on_grid() {
        let a = gen::grid2d_laplacian(9, 9).plus_transpose_pattern();
        let p = min_degree(&a);
        assert!(p.is_valid());
        assert_eq!(p.len(), 81);
    }

    #[test]
    fn arrow_up_is_fixed_by_min_degree() {
        // arrow_up under natural ordering → full fill; MD finds the
        // no-fill elimination (hub last).
        let a = gen::arrow_up(60);
        let natural = fill_nnz(&a, &Permutation::identity(60));
        let md = fill_nnz(&a, &min_degree(&a.plus_transpose_pattern()));
        assert!(md < natural / 4, "md fill {md}, natural fill {natural}");
        // optimum is nnz(A): 3n-2 entries
        assert_eq!(md, 3 * 60 - 2);
    }

    #[test]
    fn reduces_fill_on_2d_grid_vs_natural() {
        let a = gen::grid2d_laplacian(16, 16);
        let natural = fill_nnz(&a, &Permutation::identity(256));
        let md = fill_nnz(&a, &min_degree(&a.plus_transpose_pattern()));
        assert!(md < natural, "md {md} natural {natural}");
    }

    #[test]
    fn handles_diagonal_only_matrix() {
        let a = Csc::identity(5);
        let p = min_degree(&a);
        assert!(p.is_valid());
    }

    #[test]
    fn handles_empty_matrix() {
        let p = min_degree(&Csc::zeros(0, 0));
        assert_eq!(p.len(), 0);
    }

    #[test]
    fn deterministic() {
        let a = gen::directed_graph(150, 4, 3).plus_transpose_pattern();
        assert_eq!(min_degree(&a), min_degree(&a));
    }
}
