//! Block Triangular Form: Tarjan's strongly-connected components over the
//! directed graph of A, ordered topologically.
//!
//! This is the decomposition KLU and Basker build on (paper Table 1 —
//! "Block diagonal" / "Recursive block diagonal"): permuting `P A Pᵀ` to
//! block *lower* triangular form lets each diagonal block factor
//! independently, with no fill between blocks. Provided as a preprocessing
//! alternative/complement to the paper's 2D blocking (and used by the
//! comparison tooling).

use super::Permutation;
use crate::sparse::Csc;

/// Result of the BTF decomposition.
#[derive(Clone, Debug)]
pub struct Btf {
    /// Symmetric permutation (old → new) sorting vertices by SCC in
    /// topological order of the condensation.
    pub perm: Permutation,
    /// Block boundaries in the new ordering: `blocks[k]..blocks[k+1]` is
    /// the k-th strongly-connected diagonal block.
    pub blocks: Vec<usize>,
}

impl Btf {
    pub fn num_blocks(&self) -> usize {
        self.blocks.len() - 1
    }

    /// Size of the largest diagonal block — 1 means A is permutable to
    /// (fully) triangular form.
    pub fn max_block(&self) -> usize {
        self.blocks
            .windows(2)
            .map(|w| w[1] - w[0])
            .max()
            .unwrap_or(0)
    }
}

/// Compute the BTF of (the directed graph of) square `a`, using an
/// iterative Tarjan SCC (explicit stack — no recursion depth limits).
///
/// Tarjan emits SCCs in *reverse* topological order of the condensation;
/// reversing yields an ordering where every edge between distinct blocks
/// points from an earlier block to a later one — i.e. `P A Pᵀ` is block
/// **lower** triangular when edge `(i,j)` means `A[i,j] ≠ 0` is read as
/// j → i… we orient so that the permuted matrix is block lower
/// triangular: entry (i,j) with block(i) < block(j) is impossible.
pub fn btf(a: &Csc) -> Btf {
    let n = a.n_cols();
    assert_eq!(a.n_rows(), n, "BTF needs a square matrix");

    // adjacency: edge j -> i for every entry A[i,j] (a column reaches its
    // rows); Tarjan over this digraph.
    let mut index = vec![usize::MAX; n]; // discovery index
    let mut low = vec![0usize; n];
    let mut on_stack = vec![false; n];
    let mut stack: Vec<usize> = Vec::new();
    let mut comp_of = vec![usize::MAX; n];
    let mut num_comps = 0usize;
    let mut next_index = 0usize;

    // explicit DFS stack: (vertex, edge cursor)
    let mut dfs: Vec<(usize, usize)> = Vec::new();
    for root in 0..n {
        if index[root] != usize::MAX {
            continue;
        }
        dfs.push((root, 0));
        index[root] = next_index;
        low[root] = next_index;
        next_index += 1;
        stack.push(root);
        on_stack[root] = true;

        while let Some(&mut (v, ref mut cursor)) = dfs.last_mut() {
            let rows = a.col_rows(v);
            if *cursor < rows.len() {
                let w = rows[*cursor];
                *cursor += 1;
                if w == v {
                    continue; // self loop
                }
                if index[w] == usize::MAX {
                    index[w] = next_index;
                    low[w] = next_index;
                    next_index += 1;
                    stack.push(w);
                    on_stack[w] = true;
                    dfs.push((w, 0));
                } else if on_stack[w] {
                    low[v] = low[v].min(index[w]);
                }
            } else {
                // retreat
                dfs.pop();
                if let Some(&mut (parent, _)) = dfs.last_mut() {
                    low[parent] = low[parent].min(low[v]);
                }
                if low[v] == index[v] {
                    // v is an SCC root
                    loop {
                        let w = stack.pop().unwrap();
                        on_stack[w] = false;
                        comp_of[w] = num_comps;
                        if w == v {
                            break;
                        }
                    }
                    num_comps += 1;
                }
            }
        }
    }

    // Tarjan emits components in reverse topological order of the
    // condensation of the edge direction we traversed (v → rows of col v).
    // With comp ids assigned in emission order, an edge col v → row w
    // between distinct comps satisfies comp_of[w] < comp_of[v]… i.e. for
    // entry A[w, v]: comp(row) ≤ comp(col). Ordering blocks by comp id
    // ascending therefore makes the permuted matrix block *upper*
    // triangular; we want lower, so order by comp id descending.
    let mut comp_sizes = vec![0usize; num_comps];
    for &c in &comp_of {
        comp_sizes[c] += 1;
    }
    // new block order: descending comp id
    let mut block_start = vec![0usize; num_comps + 1];
    for k in 0..num_comps {
        let c = num_comps - 1 - k; // comp id placed at block k
        block_start[k + 1] = block_start[k] + comp_sizes[c];
    }
    let mut cursor = block_start.clone();
    let mut perm = vec![0usize; n];
    for old in 0..n {
        let k = num_comps - 1 - comp_of[old];
        perm[old] = cursor[k];
        cursor[k] += 1;
    }
    Btf {
        perm: Permutation::from_vec(perm),
        blocks: block_start,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::{gen, Coo};

    fn assert_block_lower(a: &Csc, btf: &Btf) {
        let pa = a.permute_sym(btf.perm.as_slice());
        // block index of each new position
        let mut blk = vec![0usize; pa.n_cols()];
        for k in 0..btf.num_blocks() {
            for p in btf.blocks[k]..btf.blocks[k + 1] {
                blk[p] = k;
            }
        }
        for j in 0..pa.n_cols() {
            for (i, _) in pa.col(j) {
                assert!(
                    blk[i] >= blk[j],
                    "entry ({i},{j}) above the block diagonal: blocks {} < {}",
                    blk[i],
                    blk[j]
                );
            }
        }
    }

    #[test]
    fn lower_triangular_matrix_gives_singleton_blocks() {
        // strictly lower triangular + diagonal: every vertex its own SCC
        let mut coo = Coo::new(5, 5);
        for i in 0..5 {
            coo.push(i, i, 1.0);
        }
        coo.push(3, 1, 1.0);
        coo.push(4, 0, 1.0);
        let a = coo.to_csc();
        let d = btf(&a);
        assert_eq!(d.num_blocks(), 5);
        assert_eq!(d.max_block(), 1);
        assert_block_lower(&a, &d);
    }

    #[test]
    fn directed_cycle_is_one_block() {
        let mut coo = Coo::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 2.0);
            coo.push((i + 1) % 4, i, 1.0); // cycle 0→1→2→3→0
        }
        let a = coo.to_csc();
        let d = btf(&a);
        assert_eq!(d.num_blocks(), 1);
        assert_eq!(d.max_block(), 4);
    }

    #[test]
    fn two_sccs_with_coupling_order_correctly() {
        // SCC A = {0,1} (cycle), SCC B = {2,3} (cycle), edge from A-col to
        // B-row: A[2,0] ≠ 0 means block(B) depends on block(A) downstream.
        let mut coo = Coo::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 2.0);
        }
        coo.push(1, 0, 1.0);
        coo.push(0, 1, 1.0);
        coo.push(3, 2, 1.0);
        coo.push(2, 3, 1.0);
        coo.push(2, 0, 0.5); // coupling
        let a = coo.to_csc();
        let d = btf(&a);
        assert_eq!(d.num_blocks(), 2);
        assert_eq!(d.max_block(), 2);
        assert_block_lower(&a, &d);
    }

    #[test]
    fn symmetric_connected_matrix_is_single_block() {
        let a = gen::grid2d_laplacian(6, 6);
        let d = btf(&a);
        assert_eq!(d.num_blocks(), 1);
    }

    #[test]
    fn random_digraphs_produce_valid_btf() {
        for seed in 0..6 {
            let a = gen::directed_graph(120, 2, seed);
            let d = btf(&a);
            assert!(d.perm.is_valid());
            assert_eq!(*d.blocks.last().unwrap(), 120);
            assert_block_lower(&a, &d);
        }
    }

    #[test]
    fn solving_after_btf_permutation_still_works() {
        use crate::solver::{SolveOptions, Solver};
        use crate::sparse::residual;
        let a = gen::directed_graph(200, 3, 4);
        let d = btf(&a);
        let pa = a.permute_sym(d.perm.as_slice());
        let mut solver = Solver::new(SolveOptions::ours(2));
        let f = solver.factorize(&pa).unwrap();
        let b: Vec<f64> = (0..200).map(|i| (i % 5) as f64).collect();
        let x = f.solve(&b);
        assert!(residual(&pa, &x, &b) < 1e-9);
    }
}
