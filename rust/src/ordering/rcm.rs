//! Reverse Cuthill–McKee ordering: BFS from a pseudo-peripheral vertex,
//! visiting neighbours in increasing-degree order, then reversing. Reduces
//! bandwidth, producing the near-diagonal structure regular blocking likes.

use super::Permutation;
use crate::sparse::Csc;
use std::collections::VecDeque;

/// BFS from `start` over the pattern of symmetric `m`; returns (levels,
/// last-visited vertex, eccentricity). Unreached vertices get level
/// `usize::MAX`.
fn bfs_levels(m: &Csc, start: usize) -> (Vec<usize>, usize, usize) {
    let n = m.n_cols();
    let mut level = vec![usize::MAX; n];
    let mut q = VecDeque::new();
    level[start] = 0;
    q.push_back(start);
    let mut last = start;
    let mut ecc = 0;
    while let Some(u) = q.pop_front() {
        last = u;
        ecc = level[u];
        for &v in m.col_rows(u) {
            if level[v] == usize::MAX {
                level[v] = level[u] + 1;
                q.push_back(v);
            }
        }
    }
    (level, last, ecc)
}

/// Find a pseudo-peripheral vertex of the component containing `start`
/// (George–Liu double-BFS heuristic).
fn pseudo_peripheral(m: &Csc, start: usize) -> usize {
    let (_, mut cand, mut ecc) = bfs_levels(m, start);
    loop {
        let (_, nxt, e) = bfs_levels(m, cand);
        if e > ecc {
            ecc = e;
            cand = nxt;
        } else {
            return cand;
        }
    }
}

/// Reverse Cuthill–McKee ordering of the symmetric pattern `m`
/// (callers pass `a.plus_transpose_pattern()`); handles disconnected
/// graphs by restarting from the lowest-degree unvisited vertex.
pub fn rcm(m: &Csc) -> Permutation {
    let n = m.n_cols();
    let deg: Vec<usize> = (0..n).map(|j| m.col_rows(j).len()).collect();
    let mut visited = vec![false; n];
    let mut order: Vec<usize> = Vec::with_capacity(n);
    let mut heads: Vec<usize> = (0..n).collect();
    heads.sort_unstable_by_key(|&v| deg[v]);
    let mut neigh: Vec<usize> = Vec::new();
    for &seed in &heads {
        if visited[seed] {
            continue;
        }
        let root = pseudo_peripheral(m, seed);
        // Cuthill–McKee BFS from root
        let mut q = VecDeque::new();
        visited[root] = true;
        q.push_back(root);
        while let Some(u) = q.pop_front() {
            order.push(u);
            neigh.clear();
            for &v in m.col_rows(u) {
                if !visited[v] {
                    visited[v] = true;
                    neigh.push(v);
                }
            }
            neigh.sort_unstable_by_key(|&v| deg[v]);
            for &v in &neigh {
                q.push_back(v);
            }
        }
    }
    order.reverse();
    Permutation::from_order(&order)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn bandwidth(m: &Csc) -> usize {
        let mut bw = 0usize;
        for j in 0..m.n_cols() {
            for &i in m.col_rows(j) {
                bw = bw.max(i.abs_diff(j));
            }
        }
        bw
    }

    #[test]
    fn rcm_is_valid_permutation() {
        let a = gen::grid2d_laplacian(10, 10).plus_transpose_pattern();
        let p = rcm(&a);
        assert!(p.is_valid());
    }

    #[test]
    fn rcm_reduces_bandwidth_of_shuffled_grid() {
        // Shuffle a grid, then check RCM restores small bandwidth.
        let a = gen::grid2d_laplacian(12, 12);
        let mut rng = crate::util::Prng::new(99);
        let mut shuffled: Vec<usize> = (0..a.n_cols()).collect();
        rng.shuffle(&mut shuffled);
        let shuffle = Permutation::from_vec(shuffled);
        let bad = a.permute_sym(shuffle.as_slice());
        let sym = bad.plus_transpose_pattern();
        let p = rcm(&sym);
        let good = bad.permute_sym(p.as_slice());
        assert!(
            bandwidth(&good) < bandwidth(&bad) / 2,
            "rcm bw {} vs shuffled bw {}",
            bandwidth(&good),
            bandwidth(&bad)
        );
    }

    #[test]
    fn rcm_handles_disconnected_graph() {
        // two disjoint tridiagonal components
        let mut coo = crate::sparse::Coo::new(8, 8);
        for i in 0..3 {
            coo.push_sym(i, i + 1, 1.0);
        }
        for i in 4..7 {
            coo.push_sym(i, i + 1, 1.0);
        }
        for i in 0..8 {
            coo.push(i, i, 4.0);
        }
        let m = coo.to_csc();
        let p = rcm(&m);
        assert!(p.is_valid());
        assert_eq!(p.len(), 8);
    }

    #[test]
    fn arrow_up_gets_reordered_to_low_fill_position() {
        // RCM on the arrow-up matrix pushes the hub away from position 0.
        let a = gen::arrow_up(50).plus_transpose_pattern();
        let p = rcm(&a);
        assert!(p.is_valid());
        // hub (old index 0) should end up in the last half under RCM
        assert!(p.apply(0) >= 25, "hub placed at {}", p.apply(0));
    }
}
