//! Synthetic matrix generators.
//!
//! The paper evaluates on ten SuiteSparse matrices (Table 3). Offline we
//! reproduce each *kind* with a generator that matches its post-symbolic
//! nonzero-distribution archetype (§4.2, Figs 7–8, 11):
//!
//! | paper matrix        | kind                         | generator |
//! |---------------------|------------------------------|-----------|
//! | ecology1, G3_circuit| 2D/3D problem, circuit grid  | [`grid2d_laplacian`] |
//! | apache2, boneS10    | structural / model reduction | [`grid3d_laplacian`], [`banded_fem`] |
//! | ASIC_680k           | circuit with dense borders   | [`circuit_bbd`] |
//! | cage12, language    | directed weighted graph      | [`directed_graph`] |
//! | offshore, dielFilter| electromagnetics             | [`electromagnetics_like`] |
//! | CoupCons3D, inline_1| structural, coupled          | [`banded_fem`] |
//!
//! Every generator returns a **row-wise diagonally dominant** matrix so the
//! no-pivot numeric factorization (the paper's setting: stability handled in
//! reordering) is well defined, and every matrix has a full structural
//! diagonal.

use super::{Coo, Csc};
use crate::util::Prng;

/// Accumulate off-diagonal entries, then set each diagonal to
/// `rowsum_abs + shift` so the matrix is strictly diagonally dominant.
fn finish_diag_dominant(n: usize, coo: &mut Coo, shift: f64) -> Csc {
    // Sum duplicates first by converting, then recompute diagonal.
    let m = coo.to_csc();
    let mut row_abs = vec![0.0f64; n];
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                row_abs[i] += v.abs();
            }
        }
    }
    let mut out = Coo::with_capacity(n, n, m.nnz() + n);
    for j in 0..n {
        for (i, v) in m.col(j) {
            if i != j {
                out.push(i, j, v);
            }
        }
    }
    for i in 0..n {
        out.push(i, i, row_abs[i] + shift);
    }
    out.to_csc()
}

/// 5-point 2D Laplacian on an `nx × ny` grid (dimension `nx*ny`).
/// The classic "2D/3D problem" matrix (ecology1-like): nonzeros distributed
/// uniformly along the diagonal — the *linear* archetype of Fig 7(a).
pub fn grid2d_laplacian(nx: usize, ny: usize) -> Csc {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let idx = |x: usize, y: usize| y * nx + x;
    for y in 0..ny {
        for x in 0..nx {
            let c = idx(x, y);
            coo.push(c, c, 4.0 + 1.0);
            if x + 1 < nx {
                coo.push(c, idx(x + 1, y), -1.0);
                coo.push(idx(x + 1, y), c, -1.0);
            }
            if y + 1 < ny {
                coo.push(c, idx(x, y + 1), -1.0);
                coo.push(idx(x, y + 1), c, -1.0);
            }
        }
    }
    coo.to_csc()
}

/// 7-point 3D Laplacian on an `nx × ny × nz` grid — apache2-like
/// structural problem.
pub fn grid3d_laplacian(nx: usize, ny: usize, nz: usize) -> Csc {
    let n = nx * ny * nz;
    let mut coo = Coo::with_capacity(n, n, 7 * n);
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let c = idx(x, y, z);
                coo.push(c, c, 6.0 + 1.0);
                if x + 1 < nx {
                    coo.push_sym(c, idx(x + 1, y, z), -1.0);
                }
                if y + 1 < ny {
                    coo.push_sym(c, idx(x, y + 1, z), -1.0);
                }
                if z + 1 < nz {
                    coo.push_sym(c, idx(x, y, z + 1), -1.0);
                }
            }
        }
    }
    coo.to_csc()
}

/// Parameters for [`circuit_bbd`].
#[derive(Clone, Copy, Debug)]
pub struct CircuitParams {
    /// Total dimension.
    pub n: usize,
    /// Fraction of rows/cols forming the dense border at the bottom-right
    /// (ASIC_680k concentrates ~98% of nonzeros there).
    pub border_frac: f64,
    /// Density of the border block coupling (0..1).
    pub border_density: f64,
    /// Average off-diagonal nonzeros per interior row (near-diagonal).
    pub interior_deg: usize,
    /// PRNG seed.
    pub seed: u64,
}

impl Default for CircuitParams {
    fn default() -> Self {
        Self { n: 4000, border_frac: 0.06, border_density: 0.25, interior_deg: 3, seed: 0xA51C }
    }
}

/// Circuit-simulation matrix with Bordered Block Diagonal structure:
/// a sparse near-diagonal interior plus dense border rows/columns at the
/// bottom-right — the ASIC_680k archetype (Fig 11 left: ~98% of nonzeros
/// in the bottom/right region after symbolic factorization).
pub fn circuit_bbd(p: CircuitParams) -> Csc {
    let n = p.n;
    let border = ((n as f64 * p.border_frac) as usize).max(1);
    let interior = n - border;
    let mut rng = Prng::new(p.seed);
    let mut coo = Coo::with_capacity(n, n, n * (p.interior_deg + 2));
    // Interior: short-range couplings (circuit locality).
    for i in 0..interior {
        for _ in 0..p.interior_deg {
            let span = 1 + rng.below(16.min(interior));
            let j = if rng.f64() < 0.5 {
                i.saturating_sub(span)
            } else {
                (i + span).min(interior - 1)
            };
            if j != i {
                coo.push(i, j, -rng.range_f64(0.1, 1.0));
            }
        }
        // sparse coupling into the border (every interior node touches
        // a couple of border nets — supply rails, clocks).
        let hits = 1 + rng.below(2);
        for _ in 0..hits {
            let b = interior + rng.below(border);
            let v = -rng.range_f64(0.1, 1.0);
            coo.push(i, b, v);
            coo.push(b, i, v);
        }
    }
    // Border block: dense-ish coupling among border nodes.
    for bi in 0..border {
        for bj in 0..border {
            if bi != bj && rng.f64() < p.border_density {
                coo.push(interior + bi, interior + bj, -rng.range_f64(0.1, 1.0));
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Directed weighted graph matrix (cage12 / language archetype):
/// unsymmetric pattern, moderate average degree, entries scattered
/// broadly so symbolic factorization produces heavy fill.
pub fn directed_graph(n: usize, avg_deg: usize, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (avg_deg + 1));
    for i in 0..n {
        // mix of local edges and long-range hops (power-law-ish reach)
        for _ in 0..avg_deg {
            let j = if rng.f64() < 0.7 {
                // local: within a window
                let w = 1 + rng.below(32.min(n));
                if rng.f64() < 0.5 { i.saturating_sub(w) } else { (i + w).min(n - 1) }
            } else {
                rng.below(n)
            };
            if j != i {
                coo.push(i, j, rng.signed_unit());
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Banded FEM-like structural matrix (CoupCons3D / boneS10 / inline_1):
/// several off-diagonal bands with small random block coupling, i.e. a
/// multi-banded symmetric pattern.
pub fn banded_fem(n: usize, bands: &[usize], band_fill: f64, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (2 * bands.len() + 1));
    for i in 0..n {
        for &b in bands {
            if i + b < n && rng.f64() < band_fill {
                coo.push_sym(i, i + b, -rng.range_f64(0.2, 1.0));
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Electromagnetics-like matrix (offshore / dielFilterV3real): clustered
/// dense element blocks along the diagonal plus sparse long-range coupling.
pub fn electromagnetics_like(n: usize, cluster: usize, coupling_deg: usize, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (cluster + coupling_deg));
    let mut start = 0usize;
    while start < n {
        let len = (cluster / 2 + rng.below(cluster.max(1))).clamp(2, n - start);
        // dense element block
        for a in 0..len {
            for b in (a + 1)..len {
                if rng.f64() < 0.7 {
                    coo.push_sym(start + a, start + b, -rng.range_f64(0.05, 0.5));
                }
            }
        }
        start += len;
    }
    // long-range couplings
    for i in 0..n {
        for _ in 0..coupling_deg {
            let j = rng.below(n);
            if j != i && rng.f64() < 0.5 {
                coo.push_sym(i, j, -rng.range_f64(0.01, 0.2));
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Arrow matrix pointing "up": dense FIRST row and column plus diagonal.
/// Under natural ordering this suffers full fill-in — Fig 2(a).
pub fn arrow_up(n: usize) -> Csc {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 1..n {
        coo.push(0, i, -1.0);
        coo.push(i, 0, -1.0);
    }
    for i in 0..n {
        let deg = if i == 0 { 2.0 * (n as f64 - 1.0) } else { 2.0 };
        coo.push(i, i, deg + 1.0);
    }
    coo.to_csc()
}

/// Arrow matrix pointing "down": dense LAST row and column plus diagonal.
/// Suffers NO fill-in — Fig 2(b). `arrow_up` reordered optimally.
pub fn arrow_down(n: usize) -> Csc {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    let last = n - 1;
    for i in 0..last {
        coo.push(last, i, -1.0);
        coo.push(i, last, -1.0);
    }
    for i in 0..n {
        let deg = if i == last { 2.0 * (n as f64 - 1.0) } else { 2.0 };
        coo.push(i, i, deg + 1.0);
    }
    coo.to_csc()
}

/// Tridiagonal matrix — the pure *linear* nonzero-distribution archetype
/// (Fig 7(a)): nnz grows uniformly along the diagonal.
pub fn tridiagonal(n: usize) -> Csc {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 0..n {
        coo.push(i, i, 3.0);
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
            coo.push(i + 1, i, -1.0);
        }
    }
    coo.to_csc()
}

/// Uniform random sparse matrix — the *quadratic* distribution archetype
/// (Fig 7(b)): nnz of the leading k×k submatrix grows ∝ k².
pub fn uniform_random(n: usize, density: f64, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let target = ((n * n) as f64 * density) as usize;
    let mut coo = Coo::with_capacity(n, n, target + n);
    for _ in 0..target {
        let i = rng.below(n);
        let j = rng.below(n);
        if i != j {
            coo.push(i, j, rng.signed_unit());
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Matrix with a few local dense diagonal regions — Fig 8(a): the feature
/// curve shows partial quadratic trends with discontinuities.
pub fn local_dense_blocks(n: usize, blocks: &[(usize, usize)], base_deg: usize, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * base_deg);
    // sparse background near the diagonal
    for i in 0..n {
        for _ in 0..base_deg {
            let w = 1 + rng.below(8);
            let j = if rng.f64() < 0.5 { i.saturating_sub(w) } else { (i + w).min(n - 1) };
            if j != i {
                coo.push(i, j, -rng.range_f64(0.1, 0.5));
            }
        }
    }
    // dense square regions [start, start+len) on the diagonal
    for &(start, len) in blocks {
        let end = (start + len).min(n);
        for a in start..end {
            for b in (a + 1)..end {
                if rng.f64() < 0.6 {
                    coo.push_sym(a, b, -rng.range_f64(0.05, 0.3));
                }
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Matrix with a few dense rows AND columns — Fig 8(b): the feature curve
/// shows jump discontinuities at the dense row/col indices.
pub fn dense_rows_cols(n: usize, dense_idx: &[usize], base_deg: usize, seed: u64) -> Csc {
    let mut rng = Prng::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * base_deg + dense_idx.len() * n);
    for i in 0..n {
        for _ in 0..base_deg {
            let w = 1 + rng.below(8);
            let j = if rng.f64() < 0.5 { i.saturating_sub(w) } else { (i + w).min(n - 1) };
            if j != i {
                coo.push(i, j, -rng.range_f64(0.1, 0.5));
            }
        }
    }
    for &d in dense_idx {
        assert!(d < n);
        for j in 0..n {
            if j != d && rng.f64() < 0.8 {
                coo.push(d, j, -rng.range_f64(0.05, 0.3));
                coo.push(j, d, -rng.range_f64(0.05, 0.3));
            }
        }
    }
    finish_diag_dominant(n, &mut coo, 1.0)
}

/// Dense column-major diagonally-dominant `n×n` buffer — the shared seed
/// for dense-kernel unit tests, the kernel differential rig, and the
/// kernel bench harness (replaces the `random_dd` helpers that used to be
/// duplicated in `numeric/dense.rs` tests).
pub fn dense_dd(n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    let mut a = vec![0.0; n * n];
    for j in 0..n {
        for i in 0..n {
            if i != j {
                a[j * n + i] = rng.signed_unit();
            }
        }
    }
    for i in 0..n {
        let row_sum: f64 = (0..n).filter(|&j| j != i).map(|j| a[j * n + i].abs()).sum();
        a[i * n + i] = row_sum + 1.0;
    }
    a
}

/// Dense column-major `m×n` buffer of uniform `[-1, 1)` values (panel
/// operand generator for the TRSM/GEMM differential tests and benches).
pub fn dense_uniform(m: usize, n: usize, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    (0..m * n).map(|_| rng.signed_unit()).collect()
}

/// [`dense_dd`] with each off-diagonal entry kept with probability
/// `density` (the rest stay structural zeros in the dense buffer). The
/// diagonal is always present and re-dominates whatever survives, so the
/// matrix is nonsingular at every density — the knob the kernel bench and
/// differential rig turn to emulate sparse-fill vs dense-region blocks
/// flowing into the dense kernels.
pub fn dense_dd_density(n: usize, density: f64, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    let mut a = vec![0.0; n * n];
    for j in 0..n {
        for i in 0..n {
            if i != j && rng.f64() < density {
                a[j * n + i] = rng.signed_unit();
            }
        }
    }
    for i in 0..n {
        let row_sum: f64 = (0..n).filter(|&j| j != i).map(|j| a[j * n + i].abs()).sum();
        a[i * n + i] = row_sum + 1.0;
    }
    a
}

/// [`dense_uniform`] with each entry kept with probability `density`
/// (`density` = 0.0 gives the all-zero "empty pattern" panel the
/// differential rig uses as a degenerate case).
pub fn dense_uniform_density(m: usize, n: usize, density: f64, seed: u64) -> Vec<f64> {
    let mut rng = Prng::new(seed);
    (0..m * n)
        .map(|_| {
            // consume the keep/value draws unconditionally so streams at
            // different densities stay aligned per entry
            let keep = rng.f64() < density;
            let v = rng.signed_unit();
            if keep {
                v
            } else {
                0.0
            }
        })
        .collect()
}

/// Fraction of nonzero entries in a dense buffer (the *achieved* density
/// the bench records next to the requested one).
pub fn buffer_density(buf: &[f64]) -> f64 {
    if buf.is_empty() {
        return 0.0;
    }
    buf.iter().filter(|v| **v != 0.0).count() as f64 / buf.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_diag_dominant(m: &Csc) -> bool {
        let n = m.n_rows();
        let mut diag = vec![0.0; n];
        let mut off = vec![0.0; n];
        for j in 0..n {
            for (i, v) in m.col(j) {
                if i == j {
                    diag[i] = v.abs();
                } else {
                    off[i] += v.abs();
                }
            }
        }
        (0..n).all(|i| diag[i] > off[i])
    }

    #[test]
    fn grid2d_shape_and_pattern() {
        let m = grid2d_laplacian(4, 3);
        assert_eq!(m.n_rows(), 12);
        m.validate().unwrap();
        assert!(m.has_full_diagonal());
        assert!(is_diag_dominant(&m));
        // interior node has 4 neighbours
        assert_eq!(m.col_rows(5).len(), 5); // self + 4
    }

    #[test]
    fn grid3d_shape() {
        let m = grid3d_laplacian(3, 3, 3);
        assert_eq!(m.n_rows(), 27);
        m.validate().unwrap();
        assert!(is_diag_dominant(&m));
    }

    #[test]
    fn circuit_bbd_concentrates_border() {
        let p = CircuitParams { n: 600, border_frac: 0.1, ..Default::default() };
        let m = circuit_bbd(p);
        m.validate().unwrap();
        assert!(m.has_full_diagonal());
        assert!(is_diag_dominant(&m));
        // the border block (last 10% rows/cols) should be much denser than
        // an interior window of the same size
        let border_start = 540;
        let mut border_nnz = 0usize;
        let mut interior_nnz = 0usize;
        for j in 0..600 {
            for (i, _) in m.col(j) {
                if i >= border_start && j >= border_start {
                    border_nnz += 1;
                }
                if (100..160).contains(&i) && (100..160).contains(&j) {
                    interior_nnz += 1;
                }
            }
        }
        assert!(border_nnz > 4 * interior_nnz, "border {border_nnz} vs interior {interior_nnz}");
    }

    #[test]
    fn directed_graph_is_unsymmetric_but_dominant() {
        let m = directed_graph(300, 4, 7);
        m.validate().unwrap();
        assert!(is_diag_dominant(&m));
        // pattern should not be symmetric (directed edges)
        let mut asym = 0;
        for j in 0..300 {
            for (i, _) in m.col(j) {
                if i != j && m.get(j, i) == 0.0 {
                    asym += 1;
                }
            }
        }
        assert!(asym > 0);
    }

    #[test]
    fn banded_fem_has_bands() {
        let m = banded_fem(200, &[1, 10, 40], 1.0, 3);
        m.validate().unwrap();
        assert!(is_diag_dominant(&m));
        assert_ne!(m.get(0, 40), 0.0);
        assert_ne!(m.get(40, 0), 0.0);
    }

    #[test]
    fn electromagnetics_reasonable() {
        let m = electromagnetics_like(400, 12, 2, 11);
        m.validate().unwrap();
        assert!(is_diag_dominant(&m));
        assert!(m.nnz() > 400 * 4);
    }

    #[test]
    fn arrows_have_expected_pattern() {
        let up = arrow_up(10);
        let down = arrow_down(10);
        up.validate().unwrap();
        down.validate().unwrap();
        assert_eq!(up.nnz(), down.nnz());
        assert_ne!(up.get(0, 9), 0.0);
        assert_eq!(up.get(9, 5), 0.0);
        assert_ne!(down.get(9, 5), 0.0);
        assert!(is_diag_dominant(&up));
        assert!(is_diag_dominant(&down));
    }

    #[test]
    fn tridiagonal_pattern() {
        let m = tridiagonal(50);
        assert_eq!(m.nnz(), 50 + 2 * 49);
        assert!(is_diag_dominant(&m));
    }

    #[test]
    fn uniform_random_density() {
        let m = uniform_random(200, 0.02, 5);
        m.validate().unwrap();
        assert!(is_diag_dominant(&m));
        let d = m.density();
        assert!(d > 0.01 && d < 0.04, "density {d}");
    }

    #[test]
    fn local_dense_blocks_denser_inside() {
        let m = local_dense_blocks(300, &[(100, 40)], 2, 9);
        m.validate().unwrap();
        let mut inside = 0usize;
        let mut outside = 0usize;
        for j in 0..300 {
            for (i, _) in m.col(j) {
                if (100..140).contains(&i) && (100..140).contains(&j) {
                    inside += 1;
                } else if (200..240).contains(&i) && (200..240).contains(&j) {
                    outside += 1;
                }
            }
        }
        assert!(inside > 3 * outside, "inside {inside} outside {outside}");
    }

    #[test]
    fn dense_rows_cols_present() {
        let m = dense_rows_cols(300, &[150], 2, 13);
        m.validate().unwrap();
        let csr = m.to_csr();
        let row_n = csr.row_cols(150).len();
        let typical = csr.row_cols(40).len();
        assert!(row_n > 5 * typical, "dense row {row_n} vs typical {typical}");
    }

    #[test]
    fn generators_are_deterministic() {
        let a = directed_graph(100, 3, 42);
        let b = directed_graph(100, 3, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn density_generators_hit_their_targets() {
        let n = 64;
        for &d in &[0.0, 0.25, 0.5, 1.0] {
            let a = dense_dd_density(n, d, 7);
            // the diagonal is always present and dominant
            for i in 0..n {
                let off: f64 =
                    (0..n).filter(|&j| j != i).map(|j| a[j * n + i].abs()).sum();
                assert!(a[i * n + i] > off, "row {i} not dominant at density {d}");
            }
            let achieved = buffer_density(&a);
            // n/(n*n) diagonal floor, Bernoulli noise on the rest
            assert!(
                (achieved - (d * (1.0 - 1.0 / n as f64) + 1.0 / n as f64)).abs() < 0.08,
                "density {d}: achieved {achieved}"
            );
            let p = dense_uniform_density(48, 32, d, 9);
            assert!((buffer_density(&p) - d).abs() < 0.08);
        }
        assert_eq!(dense_uniform_density(8, 8, 0.0, 1), vec![0.0; 64]);
        assert!(buffer_density(&dense_dd_density(n, 1.0, 3)) > 0.99, "density 1 fills");
    }
}
