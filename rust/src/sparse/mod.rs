//! Sparse matrix substrate: storage formats, conversions, IO and the
//! synthetic generators that stand in for the paper's SuiteSparse suite.

pub mod coo;
pub mod csc;
pub mod csr;
pub mod gen;
pub mod io;

pub use coo::Coo;
pub use csc::Csc;
pub use csr::Csr;

/// Relative residual `||Ax - b||_inf / ||b||_inf` — the correctness metric
/// every integration test and example checks after a solve.
pub fn residual(a: &Csc, x: &[f64], b: &[f64]) -> f64 {
    assert_eq!(x.len(), a.n_cols());
    assert_eq!(b.len(), a.n_rows());
    let mut ax = vec![0.0; a.n_rows()];
    a.mul_vec_into(x, &mut ax);
    let num = ax
        .iter()
        .zip(b)
        .map(|(axi, bi)| (axi - bi).abs())
        .fold(0.0f64, f64::max);
    let den = b.iter().map(|v| v.abs()).fold(0.0f64, f64::max);
    if den == 0.0 {
        num
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn residual_zero_for_exact_solution() {
        // A = I (2x2), x = b.
        let a = Csc::identity(2);
        let b = vec![3.0, -4.0];
        assert_eq!(residual(&a, &b, &b), 0.0);
    }

    #[test]
    fn residual_positive_for_wrong_solution() {
        let a = Csc::identity(2);
        let b = vec![1.0, 1.0];
        let x = vec![2.0, 1.0];
        assert!(residual(&a, &x, &b) > 0.5);
    }
}
