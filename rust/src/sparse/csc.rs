//! Compressed Sparse Column storage — the primary format of the solver,
//! matching the paper (§4.2: "The sparse matrix is stored by Compressed
//! Sparse Column (CSC) format").

use super::{Coo, Csr};

/// Compressed Sparse Column matrix with `f64` values.
///
/// Invariants (checked by [`Csc::validate`]):
/// * `col_ptr.len() == n_cols + 1`, `col_ptr[0] == 0`, nondecreasing;
/// * `row_idx.len() == values.len() == col_ptr[n_cols]`;
/// * row indices within each column are strictly increasing (sorted, no
///   duplicates) and `< n_rows`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    n_rows: usize,
    n_cols: usize,
    pub col_ptr: Vec<usize>,
    pub row_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csc {
    /// Build from raw parts, validating invariants.
    pub fn new(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        let m = Self { n_rows, n_cols, col_ptr, row_idx, values };
        m.validate().expect("invalid CSC");
        m
    }

    /// Build from raw parts without validation (hot paths, trusted callers).
    pub fn from_parts_unchecked(
        n_rows: usize,
        n_cols: usize,
        col_ptr: Vec<usize>,
        row_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        Self { n_rows, n_cols, col_ptr, row_idx, values }
    }

    /// Check all structural invariants.
    pub fn validate(&self) -> Result<(), String> {
        if self.col_ptr.len() != self.n_cols + 1 {
            return Err(format!(
                "col_ptr len {} != n_cols+1 {}",
                self.col_ptr.len(),
                self.n_cols + 1
            ));
        }
        if self.col_ptr[0] != 0 {
            return Err("col_ptr[0] != 0".into());
        }
        if *self.col_ptr.last().unwrap() != self.row_idx.len()
            || self.row_idx.len() != self.values.len()
        {
            return Err("nnz mismatch between col_ptr, row_idx, values".into());
        }
        for j in 0..self.n_cols {
            if self.col_ptr[j] > self.col_ptr[j + 1] {
                return Err(format!("col_ptr decreasing at {j}"));
            }
            let rng = self.col_ptr[j]..self.col_ptr[j + 1];
            for k in rng.clone() {
                if self.row_idx[k] >= self.n_rows {
                    return Err(format!("row index {} out of bounds", self.row_idx[k]));
                }
                if k > rng.start && self.row_idx[k - 1] >= self.row_idx[k] {
                    return Err(format!("unsorted/duplicate row in column {j}"));
                }
            }
        }
        Ok(())
    }

    /// n×n identity.
    pub fn identity(n: usize) -> Self {
        Self {
            n_rows: n,
            n_cols: n,
            col_ptr: (0..=n).collect(),
            row_idx: (0..n).collect(),
            values: vec![1.0; n],
        }
    }

    /// Empty (all-zero) matrix.
    pub fn zeros(n_rows: usize, n_cols: usize) -> Self {
        Self {
            n_rows,
            n_cols,
            col_ptr: vec![0; n_cols + 1],
            row_idx: Vec::new(),
            values: Vec::new(),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.row_idx.len()
    }

    /// Density nnz / (rows*cols); 0 for degenerate shapes.
    pub fn density(&self) -> f64 {
        let cells = self.n_rows as f64 * self.n_cols as f64;
        if cells == 0.0 {
            0.0
        } else {
            self.nnz() as f64 / cells
        }
    }

    /// Iterator over `(row, value)` pairs of column `j`.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        let rng = self.col_ptr[j]..self.col_ptr[j + 1];
        rng.map(move |k| (self.row_idx[k], self.values[k]))
    }

    /// Row indices of column `j`.
    pub fn col_rows(&self, j: usize) -> &[usize] {
        &self.row_idx[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Values of column `j`.
    pub fn col_values(&self, j: usize) -> &[f64] {
        &self.values[self.col_ptr[j]..self.col_ptr[j + 1]]
    }

    /// Value at `(i, j)`, 0.0 if not stored. Binary search within column.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        let rows = self.col_rows(j);
        match rows.binary_search(&i) {
            Ok(k) => self.values[self.col_ptr[j] + k],
            Err(_) => 0.0,
        }
    }

    /// Index into [`Self::values`] of the stored entry `(i, j)`, if any
    /// (`None` for out-of-range coordinates too, so callers resolving
    /// user-supplied node ids get a clean miss instead of a slice panic).
    /// This is the coordinate → value-index map change sets are built
    /// from: a [`crate::session::ChangeSet`] addresses A-nonzeros by
    /// their CSC value index, which is stable for a fixed pattern.
    pub fn value_index(&self, i: usize, j: usize) -> Option<usize> {
        if i >= self.n_rows || j >= self.n_cols {
            return None;
        }
        self.col_rows(j)
            .binary_search(&i)
            .ok()
            .map(|k| self.col_ptr[j] + k)
    }

    /// `(value index, new value)` for every entry whose value differs
    /// between `self` and `new` — the raw material of an incremental
    /// re-factorization change set. Both matrices must have the **same
    /// sparsity pattern** (shape, `col_ptr`, `row_idx`).
    pub fn value_diff(&self, new: &Csc) -> Vec<(usize, f64)> {
        assert_eq!(self.n_rows, new.n_rows, "value_diff: row count differs");
        assert_eq!(self.n_cols, new.n_cols, "value_diff: column count differs");
        assert_eq!(self.col_ptr, new.col_ptr, "value_diff: pattern differs (col_ptr)");
        assert_eq!(self.row_idx, new.row_idx, "value_diff: pattern differs (row_idx)");
        values_diff(&self.values, &new.values)
    }

    /// `y = A x` into a caller-provided buffer (cleared first).
    pub fn mul_vec_into(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.n_cols);
        assert_eq!(y.len(), self.n_rows);
        y.fill(0.0);
        for j in 0..self.n_cols {
            let xj = x[j];
            if xj == 0.0 {
                continue;
            }
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                y[self.row_idx[k]] += self.values[k] * xj;
            }
        }
    }

    /// `y = A x` (allocating).
    pub fn mul_vec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.n_rows];
        self.mul_vec_into(x, &mut y);
        y
    }

    /// Transpose (O(nnz) bucket pass); result columns are sorted.
    pub fn transpose(&self) -> Csc {
        let mut cnt = vec![0usize; self.n_rows + 1];
        for &r in &self.row_idx {
            cnt[r + 1] += 1;
        }
        for i in 0..self.n_rows {
            cnt[i + 1] += cnt[i];
        }
        let mut col_ptr = cnt.clone();
        let mut row_idx = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut next = col_ptr.clone();
        for j in 0..self.n_cols {
            for k in self.col_ptr[j]..self.col_ptr[j + 1] {
                let r = self.row_idx[k];
                let p = next[r];
                next[r] += 1;
                row_idx[p] = j;
                values[p] = self.values[k];
            }
        }
        col_ptr.truncate(self.n_rows + 1);
        Csc {
            n_rows: self.n_cols,
            n_cols: self.n_rows,
            col_ptr,
            row_idx,
            values,
        }
    }

    /// Pattern of `A + Aᵀ` (values summed; structural union). The symbolic
    /// phase runs on this symmetrized pattern, as the paper assumes the
    /// post-symbolic matrix has symmetric structure (§4.2).
    pub fn plus_transpose_pattern(&self) -> Csc {
        assert_eq!(self.n_rows, self.n_cols, "symmetrization needs square A");
        let at = self.transpose();
        let n = self.n_cols;
        let mut col_ptr = vec![0usize; n + 1];
        let mut row_idx = Vec::with_capacity(self.nnz() * 2);
        let mut values = Vec::with_capacity(self.nnz() * 2);
        for j in 0..n {
            // merge two sorted runs
            let (a_rows, a_vals) = (self.col_rows(j), self.col_values(j));
            let (b_rows, b_vals) = (at.col_rows(j), at.col_values(j));
            let (mut ia, mut ib) = (0usize, 0usize);
            while ia < a_rows.len() || ib < b_rows.len() {
                let ra = a_rows.get(ia).copied().unwrap_or(usize::MAX);
                let rb = b_rows.get(ib).copied().unwrap_or(usize::MAX);
                if ra < rb {
                    row_idx.push(ra);
                    values.push(a_vals[ia]);
                    ia += 1;
                } else if rb < ra {
                    row_idx.push(rb);
                    values.push(b_vals[ib]);
                    ib += 1;
                } else {
                    row_idx.push(ra);
                    values.push(a_vals[ia] + b_vals[ib]);
                    ia += 1;
                    ib += 1;
                }
            }
            col_ptr[j + 1] = row_idx.len();
        }
        Csc {
            n_rows: n,
            n_cols: n,
            col_ptr,
            row_idx,
            values,
        }
    }

    /// Symmetric permutation `P A Pᵀ`: entry (i,j) moves to (perm[i], perm[j]),
    /// where `perm[old] = new`.
    pub fn permute_sym(&self, perm: &[usize]) -> Csc {
        assert_eq!(self.n_rows, self.n_cols);
        assert_eq!(perm.len(), self.n_cols);
        let n = self.n_cols;
        // inverse permutation: iperm[new] = old
        let mut iperm = vec![0usize; n];
        for (old, &new) in perm.iter().enumerate() {
            iperm[new] = old;
        }
        let mut cnt = vec![0usize; n + 1];
        for new_j in 0..n {
            let old_j = iperm[new_j];
            cnt[new_j + 1] = cnt[new_j] + (self.col_ptr[old_j + 1] - self.col_ptr[old_j]);
        }
        let col_ptr = cnt;
        let mut row_idx = vec![0usize; self.nnz()];
        let mut values = vec![0.0; self.nnz()];
        let mut scratch: Vec<(usize, f64)> = Vec::new();
        for new_j in 0..n {
            let old_j = iperm[new_j];
            scratch.clear();
            for k in self.col_ptr[old_j]..self.col_ptr[old_j + 1] {
                scratch.push((perm[self.row_idx[k]], self.values[k]));
            }
            scratch.sort_unstable_by_key(|&(r, _)| r);
            let base = col_ptr[new_j];
            for (t, &(r, v)) in scratch.iter().enumerate() {
                row_idx[base + t] = r;
                values[base + t] = v;
            }
        }
        Csc {
            n_rows: n,
            n_cols: n,
            col_ptr,
            row_idx,
            values,
        }
    }

    /// Dense row-major copy (small matrices / tests only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.n_cols]; self.n_rows];
        for j in 0..self.n_cols {
            for (i, v) in self.col(j) {
                d[i][j] = v;
            }
        }
        d
    }

    /// Structural check: does the matrix have a full (nonzero-pattern)
    /// diagonal? Factorization without pivoting requires it.
    pub fn has_full_diagonal(&self) -> bool {
        if self.n_rows != self.n_cols {
            return false;
        }
        (0..self.n_cols).all(|j| self.col_rows(j).binary_search(&j).is_ok())
    }

    /// Count of nonzeros per column.
    pub fn col_counts(&self) -> Vec<usize> {
        (0..self.n_cols)
            .map(|j| self.col_ptr[j + 1] - self.col_ptr[j])
            .collect()
    }

    /// Structural fingerprint of the sparsity pattern: a 64-bit FNV-1a
    /// hash over shape, `col_ptr` and `row_idx` — **values are ignored**.
    /// Two matrices share a fingerprint iff (modulo hash collisions) they
    /// have the same pattern, which is exactly the condition under which a
    /// [`crate::session::FactorPlan`] can be reused for numeric-only
    /// re-factorization.
    pub fn pattern_fingerprint(&self) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        fn mix(h: u64, x: u64) -> u64 {
            let mut h = h;
            for shift in [0u32, 16, 32, 48] {
                h ^= (x >> shift) & 0xffff;
                h = h.wrapping_mul(PRIME);
            }
            h
        }
        let mut h = OFFSET;
        h = mix(h, self.n_rows as u64);
        h = mix(h, self.n_cols as u64);
        h = mix(h, self.nnz() as u64);
        for &p in &self.col_ptr {
            h = mix(h, p as u64);
        }
        for &r in &self.row_idx {
            h = mix(h, r as u64);
        }
        h
    }

    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.n_rows, self.n_cols);
        for j in 0..self.n_cols {
            for (i, v) in self.col(j) {
                coo.push(i, j, v);
            }
        }
        coo
    }

    pub fn to_csr(&self) -> Csr {
        let t = self.transpose();
        Csr::from_parts_unchecked(self.n_rows, self.n_cols, t.col_ptr, t.row_idx, t.values)
    }
}

/// `(index, new value)` for every position where two equal-length value
/// vectors differ — shared by [`Csc::value_diff`] and
/// [`crate::session::ChangeSet::from_values_diff`] so the diff semantics
/// (exact comparison; a NaN entry always registers as changed) live in
/// one place.
pub(crate) fn values_diff(old: &[f64], new: &[f64]) -> Vec<(usize, f64)> {
    assert_eq!(old.len(), new.len(), "value vectors must have equal length");
    old.iter()
        .zip(new)
        .enumerate()
        .filter(|(_, (o, n))| o != n)
        .map(|(k, (_, n))| (k, *n))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csc {
        // [1 0 2]
        // [0 3 0]
        // [4 0 5]
        Csc::new(
            3,
            3,
            vec![0, 2, 3, 5],
            vec![0, 2, 1, 0, 2],
            vec![1.0, 4.0, 3.0, 2.0, 5.0],
        )
    }

    #[test]
    fn get_returns_stored_and_zero() {
        let a = sample();
        assert_eq!(a.get(0, 0), 1.0);
        assert_eq!(a.get(2, 0), 4.0);
        assert_eq!(a.get(0, 1), 0.0);
        assert_eq!(a.get(2, 2), 5.0);
    }

    #[test]
    fn validate_rejects_unsorted() {
        let m = Csc::from_parts_unchecked(2, 1, vec![0, 2], vec![1, 0], vec![1.0, 2.0]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_out_of_bounds_row() {
        let m = Csc::from_parts_unchecked(2, 1, vec![0, 1], vec![5], vec![1.0]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_rejects_bad_ptr() {
        let m = Csc::from_parts_unchecked(2, 2, vec![0, 2, 1], vec![0, 1], vec![1.0, 1.0]);
        assert!(m.validate().is_err());
    }

    #[test]
    fn mul_vec_matches_dense() {
        let a = sample();
        let y = a.mul_vec(&[1.0, 2.0, 3.0]);
        assert_eq!(y, vec![1.0 + 6.0, 6.0, 4.0 + 15.0]);
    }

    #[test]
    fn transpose_round_trip() {
        let a = sample();
        let att = a.transpose().transpose();
        assert_eq!(a, att);
    }

    #[test]
    fn transpose_moves_entries() {
        let a = sample();
        let at = a.transpose();
        assert_eq!(at.get(0, 2), 4.0);
        assert_eq!(at.get(2, 0), 2.0);
    }

    #[test]
    fn plus_transpose_pattern_is_symmetric() {
        let a = sample();
        let s = a.plus_transpose_pattern();
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(s.get(i, j) != 0.0, s.get(j, i) != 0.0, "({i},{j})");
            }
        }
        // diagonal entries are doubled, off-diag pairs summed
        assert_eq!(s.get(0, 0), 2.0);
        assert_eq!(s.get(0, 2), 2.0 + 4.0);
    }

    #[test]
    fn permute_sym_identity_is_noop() {
        let a = sample();
        assert_eq!(a.permute_sym(&[0, 1, 2]), a);
    }

    #[test]
    fn permute_sym_moves_diagonal() {
        let a = sample();
        let p = [2usize, 0, 1]; // old 0 -> new 2, etc.
        let b = a.permute_sym(&p);
        assert_eq!(b.get(2, 2), a.get(0, 0));
        assert_eq!(b.get(0, 0), a.get(1, 1));
        assert_eq!(b.get(1, 1), a.get(2, 2));
        assert_eq!(b.get(p[2], p[0]), a.get(2, 0));
        assert_eq!(b.nnz(), a.nnz());
        b.validate().unwrap();
    }

    #[test]
    fn identity_has_full_diagonal() {
        assert!(Csc::identity(4).has_full_diagonal());
        assert!(!Csc::zeros(4, 4).has_full_diagonal());
    }

    #[test]
    fn density_and_counts() {
        let a = sample();
        assert!((a.density() - 5.0 / 9.0).abs() < 1e-12);
        assert_eq!(a.col_counts(), vec![2, 1, 2]);
    }

    #[test]
    fn fingerprint_ignores_values_but_not_pattern() {
        let a = sample();
        let mut b = sample();
        for v in &mut b.values {
            *v *= 3.5;
        }
        assert_eq!(a.pattern_fingerprint(), b.pattern_fingerprint());
        // different pattern (drop one entry) must change the fingerprint
        let c = Csc::new(3, 3, vec![0, 2, 3, 4], vec![0, 2, 1, 0], vec![1.0; 4]);
        assert_ne!(a.pattern_fingerprint(), c.pattern_fingerprint());
        // and a different shape with the same arrays must too
        let d = Csc::new(4, 3, a.col_ptr.clone(), a.row_idx.clone(), a.values.clone());
        assert_ne!(a.pattern_fingerprint(), d.pattern_fingerprint());
    }

    #[test]
    fn value_index_matches_get() {
        let a = sample();
        for i in 0..3 {
            for j in 0..3 {
                match a.value_index(i, j) {
                    Some(k) => assert_eq!(a.values[k], a.get(i, j), "({i},{j})"),
                    None => assert_eq!(a.get(i, j), 0.0, "({i},{j})"),
                }
            }
        }
        assert_eq!(a.value_index(0, 0), Some(0));
        assert_eq!(a.value_index(0, 1), None);
        // out-of-range coordinates miss cleanly instead of panicking
        assert_eq!(a.value_index(0, 3), None);
        assert_eq!(a.value_index(3, 0), None);
    }

    #[test]
    fn value_diff_finds_exactly_the_changes() {
        let a = sample();
        let mut b = sample();
        b.values[1] = -7.0;
        b.values[4] = 9.5;
        let d = a.value_diff(&b);
        assert_eq!(d, vec![(1, -7.0), (4, 9.5)]);
        assert!(a.value_diff(&a.clone()).is_empty());
    }

    #[test]
    #[should_panic(expected = "pattern differs")]
    fn value_diff_rejects_different_pattern() {
        let a = sample();
        let c = Csc::new(3, 3, vec![0, 2, 3, 4], vec![0, 2, 1, 0], vec![1.0; 4]);
        let _ = a.value_diff(&c);
    }

    #[test]
    fn csr_round_trip_preserves_entries() {
        let a = sample();
        let csr = a.to_csr();
        assert_eq!(csr.get(2, 0), 4.0);
        assert_eq!(csr.get(0, 2), 2.0);
        let back = csr.to_csc();
        assert_eq!(a, back);
    }
}
