//! MatrixMarket coordinate-format IO.
//!
//! The paper's suite comes from the SuiteSparse Matrix Collection, which
//! ships `.mtx` files in this format. The reproduction uses synthetic
//! analogues by default, but real SuiteSparse downloads drop in unchanged
//! through [`read_matrix_market`].

use super::{Coo, Csc};
use anyhow::{bail, Context, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Symmetry declared in the MatrixMarket header.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MmSymmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a MatrixMarket coordinate file into CSC. Supports `real`, `integer`
/// and `pattern` fields with `general`, `symmetric` and `skew-symmetric`
/// symmetry. Pattern entries get value 1.0.
pub fn read_matrix_market(path: impl AsRef<Path>) -> Result<Csc> {
    let f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("open {}", path.as_ref().display()))?;
    read_matrix_market_from(BufReader::new(f))
}

/// Read from any buffered reader (unit-testable without files).
pub fn read_matrix_market_from(r: impl BufRead) -> Result<Csc> {
    let mut lines = r.lines();
    let header = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                if !l.trim().is_empty() {
                    break l;
                }
            }
            None => bail!("empty MatrixMarket file"),
        }
    };
    let h = header.to_ascii_lowercase();
    if !h.starts_with("%%matrixmarket") {
        bail!("not a MatrixMarket file: header {header:?}");
    }
    let toks: Vec<&str> = h.split_whitespace().collect();
    if toks.len() < 5 || toks[1] != "matrix" || toks[2] != "coordinate" {
        bail!("only `matrix coordinate` MatrixMarket files are supported");
    }
    let field = toks[3];
    let pattern = match field {
        "real" | "integer" => false,
        "pattern" => true,
        other => bail!("unsupported field type {other:?} (complex not supported)"),
    };
    let symmetry = match toks[4] {
        "general" => MmSymmetry::General,
        "symmetric" => MmSymmetry::Symmetric,
        "skew-symmetric" => MmSymmetry::SkewSymmetric,
        other => bail!("unsupported symmetry {other:?}"),
    };

    // skip comments, read size line
    let size_line = loop {
        match lines.next() {
            Some(l) => {
                let l = l?;
                let t = l.trim();
                if t.is_empty() || t.starts_with('%') {
                    continue;
                }
                break l;
            }
            None => bail!("missing size line"),
        }
    };
    let mut it = size_line.split_whitespace();
    let n_rows: usize = it.next().context("rows")?.parse()?;
    let n_cols: usize = it.next().context("cols")?.parse()?;
    let nnz: usize = it.next().context("nnz")?.parse()?;

    let mut coo = Coo::with_capacity(n_rows, n_cols, nnz * 2);
    let mut seen = 0usize;
    for l in lines {
        let l = l?;
        let t = l.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it.next().context("row index")?.parse::<usize>()? - 1;
        let j: usize = it.next().context("col index")?.parse::<usize>()? - 1;
        let v: f64 = if pattern {
            1.0
        } else {
            it.next().context("value")?.parse()?
        };
        if i >= n_rows || j >= n_cols {
            bail!("entry ({},{}) out of declared bounds", i + 1, j + 1);
        }
        coo.push(i, j, v);
        match symmetry {
            MmSymmetry::General => {}
            MmSymmetry::Symmetric => {
                if i != j {
                    coo.push(j, i, v);
                }
            }
            MmSymmetry::SkewSymmetric => {
                if i != j {
                    coo.push(j, i, -v);
                }
            }
        }
        seen += 1;
    }
    if seen != nnz {
        bail!("declared nnz {nnz} but found {seen} entries");
    }
    Ok(coo.to_csc())
}

/// Write a CSC matrix as a `general real` MatrixMarket file.
pub fn write_matrix_market(m: &Csc, path: impl AsRef<Path>) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(f, "% written by sparselu")?;
    writeln!(f, "{} {} {}", m.n_rows(), m.n_cols(), m.nnz())?;
    for j in 0..m.n_cols() {
        for (i, v) in m.col(j) {
            writeln!(f, "{} {} {:.17e}", i + 1, j + 1, v)?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n\
                    % a comment\n\
                    2 2 3\n\
                    1 1 1.5\n\
                    2 1 -2.0\n\
                    2 2 3.0\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.n_rows(), 2);
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(0, 0), 1.5);
        assert_eq!(m.get(1, 0), -2.0);
    }

    #[test]
    fn parse_symmetric_mirrors() {
        let text = "%%MatrixMarket matrix coordinate real symmetric\n\
                    3 3 2\n\
                    2 1 5.0\n\
                    3 3 1.0\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.get(1, 0), 5.0);
        assert_eq!(m.get(0, 1), 5.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn parse_pattern_gives_unit_values() {
        let text = "%%MatrixMarket matrix coordinate pattern general\n\
                    2 2 1\n\
                    1 2\n";
        let m = read_matrix_market_from(Cursor::new(text)).unwrap();
        assert_eq!(m.get(0, 1), 1.0);
    }

    #[test]
    fn reject_wrong_header() {
        assert!(read_matrix_market_from(Cursor::new("hello\n")).is_err());
    }

    #[test]
    fn reject_nnz_mismatch() {
        let text = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n";
        assert!(read_matrix_market_from(Cursor::new(text)).is_err());
    }

    #[test]
    fn round_trip_through_file() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(2, 1, -1.25);
        coo.push(1, 2, 4.0);
        let m = coo.to_csc();
        let dir = std::env::temp_dir().join("sparselu_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("rt.mtx");
        write_matrix_market(&m, &p).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(m, back);
    }
}
