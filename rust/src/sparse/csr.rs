//! Compressed Sparse Row — used where row access dominates (dense-row
//! detection, some kernels). Thin mirror of [`super::Csc`].

use super::Csc;

/// Compressed Sparse Row matrix with `f64` values.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    n_rows: usize,
    n_cols: usize,
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<usize>,
    pub values: Vec<f64>,
}

impl Csr {
    pub fn from_parts_unchecked(
        n_rows: usize,
        n_cols: usize,
        row_ptr: Vec<usize>,
        col_idx: Vec<usize>,
        values: Vec<f64>,
    ) -> Self {
        Self { n_rows, n_cols, row_ptr, col_idx, values }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Iterator over `(col, value)` of row `i`.
    pub fn row(&self, i: usize) -> impl Iterator<Item = (usize, f64)> + '_ {
        (self.row_ptr[i]..self.row_ptr[i + 1]).map(move |k| (self.col_idx[k], self.values[k]))
    }

    /// Column indices of row `i`.
    pub fn row_cols(&self, i: usize) -> &[usize] {
        &self.col_idx[self.row_ptr[i]..self.row_ptr[i + 1]]
    }

    /// Value at `(i, j)`, 0.0 if absent.
    pub fn get(&self, i: usize, j: usize) -> f64 {
        match self.row_cols(i).binary_search(&j) {
            Ok(k) => self.values[self.row_ptr[i] + k],
            Err(_) => 0.0,
        }
    }

    /// Convert back to CSC.
    pub fn to_csc(&self) -> Csc {
        // CSR of A viewed as CSC of Aᵀ: transpose once more.
        let as_csc_of_t = Csc::from_parts_unchecked(
            self.n_cols,
            self.n_rows,
            self.row_ptr.clone(),
            self.col_idx.clone(),
            self.values.clone(),
        );
        as_csc_of_t.transpose()
    }

    /// Nonzeros per row.
    pub fn row_counts(&self) -> Vec<usize> {
        (0..self.n_rows)
            .map(|i| self.row_ptr[i + 1] - self.row_ptr[i])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use crate::sparse::Coo;

    #[test]
    fn row_access_matches_csc() {
        let mut c = Coo::new(3, 3);
        c.push(0, 1, 2.0);
        c.push(1, 0, 3.0);
        c.push(2, 2, 4.0);
        c.push(0, 2, 5.0);
        let csc = c.to_csc();
        let csr = csc.to_csr();
        assert_eq!(csr.get(0, 1), 2.0);
        assert_eq!(csr.get(0, 2), 5.0);
        assert_eq!(csr.get(1, 0), 3.0);
        assert_eq!(csr.get(1, 1), 0.0);
        assert_eq!(csr.row_counts(), vec![2, 1, 1]);
        let row0: Vec<_> = csr.row(0).collect();
        assert_eq!(row0, vec![(1, 2.0), (2, 5.0)]);
    }
}
