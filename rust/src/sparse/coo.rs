//! Coordinate (triplet) format — the assembly format: generators and the
//! MatrixMarket reader build a `Coo` and convert to CSC once.

use super::Csc;

/// Coordinate-format sparse matrix. Duplicate entries are *summed* on
/// conversion to CSC (the MatrixMarket convention).
#[derive(Clone, Debug, Default)]
pub struct Coo {
    n_rows: usize,
    n_cols: usize,
    pub rows: Vec<usize>,
    pub cols: Vec<usize>,
    pub values: Vec<f64>,
}

impl Coo {
    pub fn new(n_rows: usize, n_cols: usize) -> Self {
        Self { n_rows, n_cols, rows: Vec::new(), cols: Vec::new(), values: Vec::new() }
    }

    pub fn with_capacity(n_rows: usize, n_cols: usize, cap: usize) -> Self {
        Self {
            n_rows,
            n_cols,
            rows: Vec::with_capacity(cap),
            cols: Vec::with_capacity(cap),
            values: Vec::with_capacity(cap),
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    pub fn n_cols(&self) -> usize {
        self.n_cols
    }

    pub fn nnz(&self) -> usize {
        self.rows.len()
    }

    /// Append one entry.
    pub fn push(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.n_rows && j < self.n_cols, "entry out of bounds");
        self.rows.push(i);
        self.cols.push(j);
        self.values.push(v);
    }

    /// Append entry and its transpose mirror (skips diagonal duplication).
    pub fn push_sym(&mut self, i: usize, j: usize, v: f64) {
        self.push(i, j, v);
        if i != j {
            self.push(j, i, v);
        }
    }

    /// Convert to CSC, summing duplicates, sorting rows within columns.
    pub fn to_csc(&self) -> Csc {
        let nnz = self.nnz();
        let mut cnt = vec![0usize; self.n_cols + 1];
        for &c in &self.cols {
            cnt[c + 1] += 1;
        }
        for j in 0..self.n_cols {
            cnt[j + 1] += cnt[j];
        }
        let col_ptr_raw = cnt.clone();
        let mut next = col_ptr_raw.clone();
        let mut ridx = vec![0usize; nnz];
        let mut vals = vec![0.0; nnz];
        for k in 0..nnz {
            let c = self.cols[k];
            let p = next[c];
            next[c] += 1;
            ridx[p] = self.rows[k];
            vals[p] = self.values[k];
        }
        // sort within column + merge duplicates
        let mut out_ptr = vec![0usize; self.n_cols + 1];
        let mut out_rows = Vec::with_capacity(nnz);
        let mut out_vals = Vec::with_capacity(nnz);
        let mut buf: Vec<(usize, f64)> = Vec::new();
        for j in 0..self.n_cols {
            buf.clear();
            for k in col_ptr_raw[j]..col_ptr_raw[j + 1] {
                buf.push((ridx[k], vals[k]));
            }
            buf.sort_unstable_by_key(|&(r, _)| r);
            let mut i = 0;
            while i < buf.len() {
                let r = buf[i].0;
                let mut v = buf[i].1;
                let mut t = i + 1;
                while t < buf.len() && buf[t].0 == r {
                    v += buf[t].1;
                    t += 1;
                }
                out_rows.push(r);
                out_vals.push(v);
                i = t;
            }
            out_ptr[j + 1] = out_rows.len();
        }
        Csc::from_parts_unchecked(self.n_rows, self.n_cols, out_ptr, out_rows, out_vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csc_sorts_and_sums_duplicates() {
        let mut c = Coo::new(3, 2);
        c.push(2, 0, 1.0);
        c.push(0, 0, 2.0);
        c.push(2, 0, 3.0); // duplicate of (2,0)
        c.push(1, 1, 4.0);
        let m = c.to_csc();
        m.validate().unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.get(2, 0), 4.0);
        assert_eq!(m.get(0, 0), 2.0);
        assert_eq!(m.get(1, 1), 4.0);
    }

    #[test]
    fn push_sym_mirrors_offdiagonal() {
        let mut c = Coo::new(3, 3);
        c.push_sym(0, 2, 5.0);
        c.push_sym(1, 1, 7.0);
        let m = c.to_csc();
        assert_eq!(m.get(0, 2), 5.0);
        assert_eq!(m.get(2, 0), 5.0);
        assert_eq!(m.get(1, 1), 7.0);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn empty_coo_converts() {
        let m = Coo::new(4, 4).to_csc();
        assert_eq!(m.nnz(), 0);
        m.validate().unwrap();
    }
}
