//! `persist` — versioned, checksummed binary serialization of
//! [`FactorPlan`], so a serving process cold-starts from **one disk
//! read** instead of re-running ordering + symbolic analysis + blocking.
//!
//! What is persisted is exactly what cannot be cheaply reconstructed:
//! the solve options, the fill-reducing permutation, the pattern
//! fingerprint, the filled L+U *pattern*, the blocking boundary
//! positions, the value scatter map, and the symbolic flop count. The
//! blocked structure, task DAG, modeled schedule and reachability index
//! are deterministic functions of those parts and are rebuilt at load
//! (`FactorPlan::from_parts`) — which also means a format reader can
//! never disagree with the in-memory builders.
//!
//! Format: an 8-byte magic, a `u32` version, the payload length and an
//! FNV-1a 64 checksum over the payload, then the little-endian payload.
//! Corrupted or truncated files are rejected with a clean
//! [`PersistError`]; they never panic and never produce a plan.

use crate::blocking::{Blocking, IrregularParams};
use crate::gpu_model::CostModel;
use crate::numeric::KernelPolicy;
use crate::ordering::{OrderingMethod, Permutation};
use crate::session::plan::PlanParts;
use crate::session::{FactorPlan, PlanCache};
use crate::solver::{BlockingPolicy, SolveOptions};
use crate::sparse::Csc;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: [u8; 8] = *b"SLUPLAN\0";
const VERSION: u32 = 1;
/// File extension [`PlanCache::warm_from_dir`] scans for.
pub const PLAN_EXT: &str = "sluplan";

/// Why a plan file could not be written or read back.
#[derive(Debug)]
pub enum PersistError {
    Io(std::io::Error),
    /// The file does not start with the plan magic.
    BadMagic,
    /// The file's format version is not understood by this build.
    UnsupportedVersion(u32),
    /// The payload checksum does not match (bit rot, partial write, …).
    ChecksumMismatch,
    /// The file ends before the declared payload does.
    Truncated,
    /// The payload decoded but violates a structural invariant.
    Malformed(String),
}

impl std::fmt::Display for PersistError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PersistError::Io(e) => write!(f, "io error: {e}"),
            PersistError::BadMagic => write!(f, "not a plan file (bad magic)"),
            PersistError::UnsupportedVersion(v) => {
                write!(f, "unsupported plan format version {v} (this build reads {VERSION})")
            }
            PersistError::ChecksumMismatch => write!(f, "plan payload checksum mismatch"),
            PersistError::Truncated => write!(f, "plan file truncated"),
            PersistError::Malformed(why) => write!(f, "malformed plan payload: {why}"),
        }
    }
}

impl std::error::Error for PersistError {}

impl From<std::io::Error> for PersistError {
    fn from(e: std::io::Error) -> Self {
        PersistError::Io(e)
    }
}

/// FNV-1a 64 over a byte slice (the same family the pattern fingerprint
/// uses; collisions are irrelevant here — this guards against
/// corruption, not adversaries).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Default)]
struct ByteWriter(Vec<u8>);

impl ByteWriter {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
}

struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], PersistError> {
        let end = self.pos.checked_add(n).ok_or(PersistError::Truncated)?;
        if end > self.buf.len() {
            return Err(PersistError::Truncated);
        }
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8, PersistError> {
        Ok(self.take(1)?[0])
    }
    fn u32(&mut self) -> Result<u32, PersistError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4 bytes")))
    }
    fn u64(&mut self) -> Result<u64, PersistError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }
    fn f64(&mut self) -> Result<f64, PersistError> {
        Ok(f64::from_bits(self.u64()?))
    }
    fn len_u64(&mut self) -> Result<usize, PersistError> {
        let v = self.u64()?;
        usize::try_from(v)
            .map_err(|_| PersistError::Malformed(format!("length {v} exceeds usize")))
    }
    fn done(&self) -> bool {
        self.pos == self.buf.len()
    }
}

fn encode_options(w: &mut ByteWriter, o: &SolveOptions) {
    w.u8(match o.ordering {
        OrderingMethod::Natural => 0,
        OrderingMethod::Rcm => 1,
        OrderingMethod::MinDegree => 2,
    });
    match &o.blocking {
        BlockingPolicy::Regular(s) => {
            w.u8(0);
            w.u64(*s as u64);
        }
        BlockingPolicy::PanguSelect => {
            w.u8(1);
            w.u64(0);
        }
        BlockingPolicy::Irregular => {
            w.u8(2);
            w.u64(0);
        }
    }
    w.f64(o.kernels.dense_threshold);
    w.u8(o.kernels.force_dense as u8);
    w.u8(o.kernels.use_runtime as u8);
    let ir = &o.irregular;
    w.u64(ir.sample_points as u64);
    w.u64(ir.step as u64);
    w.u64(ir.max_num as u64);
    match ir.threshold {
        Some(t) => {
            w.u8(1);
            w.f64(t);
        }
        None => {
            w.u8(0);
            w.f64(0.0);
        }
    }
    w.u64(ir.min_block as u64);
    w.u32(o.workers);
    let m = &o.model;
    for v in [
        m.peak_flops,
        m.mem_bw,
        m.launch_overhead,
        m.eff_sparse_factor,
        m.eff_sparse_update,
        m.eff_dense,
        m.link_bw,
        m.link_latency,
        m.col_latency,
        m.col_latency_quad,
        m.sat_half_work,
    ] {
        w.f64(v);
    }
    w.u32(m.concurrent_kernels);
}

fn decode_options(r: &mut ByteReader<'_>) -> Result<SolveOptions, PersistError> {
    let ordering = match r.u8()? {
        0 => OrderingMethod::Natural,
        1 => OrderingMethod::Rcm,
        2 => OrderingMethod::MinDegree,
        t => return Err(PersistError::Malformed(format!("unknown ordering tag {t}"))),
    };
    let btag = r.u8()?;
    let bsize = r.len_u64()?;
    let blocking = match btag {
        0 => BlockingPolicy::Regular(bsize),
        1 => BlockingPolicy::PanguSelect,
        2 => BlockingPolicy::Irregular,
        t => return Err(PersistError::Malformed(format!("unknown blocking tag {t}"))),
    };
    let kernels = KernelPolicy {
        dense_threshold: r.f64()?,
        force_dense: r.u8()? != 0,
        use_runtime: r.u8()? != 0,
    };
    let sample_points = r.len_u64()?;
    let step = r.len_u64()?;
    let max_num = r.len_u64()?;
    let has_threshold = r.u8()? != 0;
    let threshold_value = r.f64()?;
    let threshold = has_threshold.then_some(threshold_value);
    let min_block = r.len_u64()?;
    let irregular = IrregularParams { sample_points, step, max_num, threshold, min_block };
    let workers = r.u32()?;
    if workers == 0 {
        return Err(PersistError::Malformed("plan options have zero workers".to_string()));
    }
    let model = CostModel {
        peak_flops: r.f64()?,
        mem_bw: r.f64()?,
        launch_overhead: r.f64()?,
        eff_sparse_factor: r.f64()?,
        eff_sparse_update: r.f64()?,
        eff_dense: r.f64()?,
        link_bw: r.f64()?,
        link_latency: r.f64()?,
        col_latency: r.f64()?,
        col_latency_quad: r.f64()?,
        sat_half_work: r.f64()?,
        concurrent_kernels: r.u32()?,
    };
    Ok(SolveOptions { ordering, blocking, kernels, irregular, workers, model })
}

fn encode_payload(plan: &FactorPlan) -> Vec<u8> {
    let mut w = ByteWriter::default();
    encode_options(&mut w, plan.options());
    w.u64(plan.fingerprint());
    w.f64(plan.report.flops);
    let perm = plan.permutation().as_slice();
    w.u64(perm.len() as u64);
    for &p in perm {
        w.u64(p as u64);
    }
    let positions = plan.structure.blocking.positions();
    w.u64(positions.len() as u64);
    for &p in positions {
        w.u64(p as u64);
    }
    let ldu = plan.structure.to_csc();
    w.u64(ldu.nnz() as u64);
    for &p in &ldu.col_ptr {
        w.u64(p as u64);
    }
    for &i in &ldu.row_idx {
        w.u64(i as u64);
    }
    let (scatter_block, scatter_off) = plan.scatter_maps();
    w.u64(scatter_block.len() as u64);
    for &b in scatter_block {
        w.u32(b);
    }
    for &o in scatter_off {
        w.u32(o);
    }
    w.0
}

fn decode_payload(payload: &[u8]) -> Result<PlanParts, PersistError> {
    let malformed = |why: &str| PersistError::Malformed(why.to_string());
    let mut r = ByteReader { buf: payload, pos: 0 };
    let opts = decode_options(&mut r)?;
    let fingerprint = r.u64()?;
    let flops = r.f64()?;

    let n = r.len_u64()?;
    let mut perm = Vec::with_capacity(n);
    for _ in 0..n {
        perm.push(r.len_u64()?);
    }
    let mut seen = vec![false; n];
    for &p in &perm {
        if p >= n || seen[p] {
            return Err(malformed("perm is not a permutation"));
        }
        seen[p] = true;
    }
    let perm = Permutation::from_vec(perm);

    let npos = r.len_u64()?;
    let mut positions = Vec::with_capacity(npos);
    for _ in 0..npos {
        positions.push(r.len_u64()?);
    }
    let valid_blocking = !positions.is_empty()
        && positions[0] == 0
        && *positions.last().unwrap() == n
        && positions.windows(2).all(|w| w[0] < w[1]);
    if !valid_blocking {
        return Err(malformed("blocking positions invalid"));
    }
    let blocking = Blocking::new(n, positions);

    let nnz_ldu = r.len_u64()?;
    let mut col_ptr = Vec::with_capacity(n + 1);
    for _ in 0..n + 1 {
        col_ptr.push(r.len_u64()?);
    }
    let mut row_idx = Vec::with_capacity(nnz_ldu);
    for _ in 0..nnz_ldu {
        row_idx.push(r.len_u64()?);
    }
    let ldu = Csc::from_parts_unchecked(n, n, col_ptr, row_idx, vec![0.0; nnz_ldu]);
    ldu.validate().map_err(PersistError::Malformed)?;

    let nnz_a = r.len_u64()?;
    let mut scatter_block = Vec::with_capacity(nnz_a);
    for _ in 0..nnz_a {
        scatter_block.push(r.u32()?);
    }
    let mut scatter_off = Vec::with_capacity(nnz_a);
    for _ in 0..nnz_a {
        scatter_off.push(r.u32()?);
    }
    if !r.done() {
        return Err(malformed("trailing bytes after payload"));
    }
    Ok(PlanParts { opts, perm, fingerprint, ldu, blocking, scatter_block, scatter_off, flops })
}

/// Serialize a session plan to `path`, crash-safely: the bytes go to a
/// temp name in the target directory, are fsynced, and the temp file is
/// renamed over `path` — a crash mid-save leaves either the old file or
/// the new one, never a torn hybrid. (A reader that still races a
/// corrupt file — torn NFS, bad disk, an injected [`crate::fault`]
/// corruption — is caught by the checksum in [`load_plan`].)
pub fn save_plan(plan: &FactorPlan, path: &Path) -> Result<(), PersistError> {
    let (scatter_block, _) = plan.scatter_maps();
    if scatter_block.len() != plan.nnz_a() {
        return Err(PersistError::Malformed(
            "plan has no scatter map (one-shot plans cannot back sessions)".to_string(),
        ));
    }
    let payload = encode_payload(plan);
    let mut out = Vec::with_capacity(28 + payload.len());
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv1a64(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    // persist fault boundary: an armed FaultPlan may flip a byte or
    // truncate here, exercising the load-side checksum/length rejects
    crate::fault::corrupt_persist(&mut out);
    // temp file in the *target* directory: rename(2) is only atomic
    // within one filesystem
    let dir = path.parent().filter(|d| !d.as_os_str().is_empty());
    let tmp = {
        let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
        name.push(".tmp");
        match dir {
            Some(d) => d.join(name),
            None => PathBuf::from(name),
        }
    };
    {
        use std::io::Write as _;
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&out)?;
        f.sync_all()?;
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

/// Canonical file name for a plan: keyed exactly like the
/// [`PlanCache`] slot it warms.
pub fn plan_file_name(plan: &FactorPlan) -> String {
    format!("plan-{:016x}.{PLAN_EXT}", PlanCache::key_of_plan(plan))
}

/// Save `plan` under its canonical name inside `dir` (created if
/// missing); returns the written path.
pub fn save_plan_to_dir(plan: &FactorPlan, dir: &Path) -> Result<PathBuf, PersistError> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(plan_file_name(plan));
    save_plan(plan, &path)?;
    Ok(path)
}

/// Deserialize a plan from `path`, verifying version and checksum, and
/// rebuild its derived structures (`FactorPlan::from_parts`).
pub fn load_plan(path: &Path) -> Result<Arc<FactorPlan>, PersistError> {
    let bytes = std::fs::read(path)?;
    let parts = decode_file(&bytes)?;
    let plan = FactorPlan::from_parts(parts).map_err(PersistError::Malformed)?;
    Ok(Arc::new(plan))
}

fn decode_file(bytes: &[u8]) -> Result<PlanParts, PersistError> {
    if bytes.len() < 28 {
        return Err(PersistError::Truncated);
    }
    if bytes[..8] != MAGIC {
        return Err(PersistError::BadMagic);
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().expect("4 bytes"));
    if version != VERSION {
        return Err(PersistError::UnsupportedVersion(version));
    }
    let payload_len = u64::from_le_bytes(bytes[12..20].try_into().expect("8 bytes"));
    let checksum = u64::from_le_bytes(bytes[20..28].try_into().expect("8 bytes"));
    let payload = &bytes[28..];
    if payload.len() as u64 != payload_len {
        return Err(PersistError::Truncated);
    }
    if fnv1a64(payload) != checksum {
        return Err(PersistError::ChecksumMismatch);
    }
    decode_payload(payload)
}

/// Result of warming a [`PlanCache`] from a directory of plan files.
#[derive(Debug)]
pub struct WarmReport {
    /// Plans loaded and inserted into the cache.
    pub loaded: usize,
    /// Files that failed to load, with the reason each was skipped —
    /// one corrupt file must not poison the rest of the warm-up.
    pub skipped: Vec<(PathBuf, PersistError)>,
}

impl PlanCache {
    /// Load every `*.sluplan` file in `dir` (sorted by name for a
    /// deterministic LRU order) into the cache. Unreadable or corrupt
    /// files are reported in [`WarmReport::skipped`] rather than
    /// aborting the warm-up; only a failure to list the directory
    /// itself is an error.
    pub fn warm_from_dir(&mut self, dir: &Path) -> Result<WarmReport, PersistError> {
        let mut paths = Vec::new();
        let mut skipped = Vec::new();
        for entry in std::fs::read_dir(dir)? {
            // a single unreadable dirent must not abort the pass — count
            // it as skipped and keep warming from the rest
            let path = match entry {
                Ok(entry) => entry.path(),
                Err(e) => {
                    skipped.push((dir.join("<unreadable dirent>"), PersistError::Io(e)));
                    continue;
                }
            };
            if path.extension().and_then(|e| e.to_str()) == Some(PLAN_EXT) {
                paths.push(path);
            }
        }
        paths.sort();
        let mut loaded = 0usize;
        for path in paths {
            match load_plan(&path) {
                Ok(plan) => {
                    self.insert(plan);
                    loaded += 1;
                }
                Err(e) => skipped.push((path, e)),
            }
        }
        Ok(WarmReport { loaded, skipped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "sparselu-persist-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn round_trip_preserves_identity_and_key() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        let opts = SolveOptions::ours(2);
        let plan = FactorPlan::build(&a, &opts).unwrap();
        let dir = tmp_dir("roundtrip");
        let path = save_plan_to_dir(&plan, &dir).unwrap();
        let loaded = load_plan(&path).unwrap();
        assert_eq!(loaded.fingerprint(), plan.fingerprint());
        assert_eq!(loaded.n(), plan.n());
        assert_eq!(loaded.nnz_a(), plan.nnz_a());
        assert!(loaded.matches(&a), "loaded plan matches the original matrix");
        assert_eq!(PlanCache::key_of_plan(&loaded), PlanCache::key_of_plan(&plan));
        assert_eq!(loaded.permutation().as_slice(), plan.permutation().as_slice());
        assert_eq!(
            loaded.structure.blocking.positions(),
            plan.structure.blocking.positions()
        );
        assert_eq!(loaded.dag.tasks.len(), plan.dag.tasks.len());
        assert_eq!(loaded.report.reorder_seconds, 0.0, "no ordering re-run at load");
        assert_eq!(loaded.report.symbolic_seconds, 0.0, "no symbolic re-run at load");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corrupted_and_truncated_files_are_rejected_cleanly() {
        let a = gen::grid2d_laplacian(7, 7);
        let plan = FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap();
        let dir = tmp_dir("corrupt");
        let path = save_plan_to_dir(&plan, &dir).unwrap();
        let good = std::fs::read(&path).unwrap();

        // flip one payload byte → checksum mismatch
        let mut bad = good.clone();
        let mid = 28 + (bad.len() - 28) / 2;
        bad[mid] ^= 0x40;
        let p = dir.join("flipped.sluplan");
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::ChecksumMismatch)));

        // cut the file short → truncated
        let p = dir.join("short.sluplan");
        std::fs::write(&p, &good[..good.len() - 9]).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::Truncated)));

        // shorter than the header → truncated
        let p = dir.join("stub.sluplan");
        std::fs::write(&p, &good[..10]).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::Truncated)));

        // wrong magic → not a plan file
        let mut bad = good.clone();
        bad[0] = b'X';
        let p = dir.join("magic.sluplan");
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::BadMagic)));

        // checksum-valid but internally inconsistent (a buggy writer):
        // wreck the last scatter offset and recompute the checksum — the
        // load must come back Malformed, not panic in the rebuild
        let mut bad = good.clone();
        let len = bad.len();
        bad[len - 4..].copy_from_slice(&u32::MAX.to_le_bytes());
        let sum = fnv1a64(&bad[28..]);
        bad[20..28].copy_from_slice(&sum.to_le_bytes());
        let p = dir.join("inconsistent.sluplan");
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::Malformed(_))));

        // future version → unsupported
        let mut bad = good;
        bad[8] = 0xFF;
        let p = dir.join("vers.sluplan");
        std::fs::write(&p, &bad).unwrap();
        assert!(matches!(load_plan(&p), Err(PersistError::UnsupportedVersion(_))));

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_from_dir_loads_good_plans_and_reports_bad_ones() {
        let dir = tmp_dir("warm");
        let opts = SolveOptions::ours(1);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let pa = FactorPlan::build(&a, &opts).unwrap();
        let pb = FactorPlan::build(&b, &opts).unwrap();
        save_plan_to_dir(&pa, &dir).unwrap();
        save_plan_to_dir(&pb, &dir).unwrap();
        std::fs::write(dir.join("junk.sluplan"), b"not a plan at all").unwrap();
        std::fs::write(dir.join("ignored.txt"), b"wrong extension").unwrap();

        let mut cache = PlanCache::new(8);
        let report = cache.warm_from_dir(&dir).unwrap();
        assert_eq!(report.loaded, 2);
        assert_eq!(report.skipped.len(), 1, "only the junk .sluplan is skipped");
        assert_eq!(cache.len(), 2);
        // warmed entries serve get_or_build without a rebuild
        let hit = cache.get_or_build(&a, &opts).unwrap();
        assert_eq!(hit.fingerprint(), a.pattern_fingerprint());
        assert_eq!((cache.hits(), cache.misses()), (1, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn one_shot_plans_refuse_to_serialize() {
        let a = gen::grid2d_laplacian(5, 5);
        let plan =
            crate::session::FactorPlan::build_for_oneshot(&a, &SolveOptions::ours(1), None)
                .unwrap();
        let dir = tmp_dir("oneshot");
        let err = save_plan(&plan, &dir.join("x.sluplan")).unwrap_err();
        assert!(matches!(err, PersistError::Malformed(_)));
        std::fs::remove_dir_all(&dir).ok();
    }
}
