//! [`SessionPool`] — N solver sessions bound to one shared
//! [`FactorPlan`], with checkout/checkin and lazy growth.
//!
//! A plan is immutable and `Arc`-shared; the *sessions* (preallocated
//! blocked value storage + scratch) are the per-client mutable state. The
//! pool keeps that storage alive across requests so concurrent clients
//! re-factorize and solve **without re-planning and without re-allocating
//! blocked storage per request** — the per-worker preallocation the 2D
//! partitioned-layout literature motivates. Checkout order is LIFO (the
//! most recently returned session is handed out next), which keeps the
//! hot session's storage warm in cache under bursty load.
//!
//! Pooled sessions also share one *process-wide* persistent
//! [`crate::coordinator::Executor`] (per worker count): draining many
//! pools/shards concurrently multiplexes their DAG runs over a single
//! set of worker threads instead of paying a `P`-thread spawn per
//! drained batch.

use crate::obs::{self, Counter, Gauge, Histogram, Registry};
use crate::session::{FactorPlan, SolverSession};
use std::ops::{Deref, DerefMut};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// Counters describing pool behavior under load.
#[derive(Clone, Copy, Debug)]
pub struct PoolStats {
    /// Sessions materialized so far (lazy growth; ≤ `max_sessions`).
    pub created: usize,
    /// Total successful checkouts.
    pub checkouts: usize,
    /// Checkouts that had to block waiting for a checkin.
    pub waits: usize,
    /// Sessions currently idle in the pool.
    pub idle: usize,
    /// Sessions currently checked out.
    pub in_use: usize,
}

struct PoolState {
    idle: Vec<SolverSession<'static>>,
    created: usize,
    checkouts: usize,
    waits: usize,
}

/// Registry handles a pool updates as it runs. Created per tenant by
/// the router (labeled `tenant="…"`) or per pool by the load generator.
pub struct PoolMetrics {
    /// `sparselu_pool_checkout_wait_seconds` — time a checkout spent
    /// acquiring a session (≈0 when one was idle or growable).
    pub checkout_wait: Histogram,
    /// `sparselu_pool_checkouts_total`.
    pub checkouts: Counter,
    /// `sparselu_pool_waits_total` — checkouts that had to block.
    pub waits: Counter,
    /// `sparselu_pool_sessions_created` — sessions materialized.
    pub created: Gauge,
    /// `sparselu_pool_sessions_in_use` — occupancy right now.
    pub in_use: Gauge,
    /// `sparselu_pool_sessions_target` — current cap (autoscaled).
    pub target: Gauge,
}

impl PoolMetrics {
    /// Get-or-create the pool series under `labels` in `registry`.
    pub fn register(registry: &Registry, labels: &[(&str, &str)]) -> Self {
        Self {
            checkout_wait: registry.histogram(
                "sparselu_pool_checkout_wait_seconds",
                "Time a session checkout spent waiting to acquire a session",
                labels,
                &obs::LATENCY_BUCKETS,
            ),
            checkouts: registry.counter(
                "sparselu_pool_checkouts_total",
                "Successful session checkouts",
                labels,
            ),
            waits: registry.counter(
                "sparselu_pool_waits_total",
                "Checkouts that blocked waiting for a checkin",
                labels,
            ),
            created: registry.gauge(
                "sparselu_pool_sessions_created",
                "Sessions materialized by the pool (lazy growth)",
                labels,
            ),
            in_use: registry.gauge(
                "sparselu_pool_sessions_in_use",
                "Sessions currently checked out",
                labels,
            ),
            target: registry.gauge(
                "sparselu_pool_sessions_target",
                "Current session cap (resized by the autoscaler)",
                labels,
            ),
        }
    }
}

/// A bounded pool of [`SolverSession`]s over one shared plan.
///
/// Sessions are created lazily: the pool starts empty and materializes a
/// new session (one blocked-storage allocation) only when a checkout
/// finds no idle session and the cap has not been reached. Past the cap,
/// [`SessionPool::checkout`] blocks until a session is returned.
///
/// ```
/// use sparselu::serve::SessionPool;
/// use sparselu::session::FactorPlan;
/// use sparselu::solver::SolveOptions;
/// use sparselu::sparse::gen;
/// use std::sync::Arc;
///
/// let a = gen::grid2d_laplacian(8, 8);
/// let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
/// let pool = SessionPool::new(plan, 4); // lazy growth up to 4 sessions
///
/// let mut session = pool.checkout();    // RAII guard; derefs to the session
/// session.refactorize(&a.values).unwrap();
/// let x = session.solve(&vec![1.0; a.n_rows()]);
/// assert_eq!(x.len(), a.n_rows());
/// drop(session);                        // checkin: factors stay warm
///
/// assert!(pool.checkout().is_factored(), "the returned session is reused");
/// assert_eq!(pool.stats().created, 1, "one allocation served both checkouts");
/// ```
pub struct SessionPool {
    plan: Arc<FactorPlan>,
    /// Atomic so the autoscaler can [`SessionPool::resize`] through a
    /// shared reference while checkouts are in flight.
    max_sessions: AtomicUsize,
    state: Mutex<PoolState>,
    cv: Condvar,
    metrics: Option<PoolMetrics>,
}

impl SessionPool {
    /// Pool over `plan`, growing lazily up to `max_sessions`.
    pub fn new(plan: Arc<FactorPlan>, max_sessions: usize) -> Self {
        assert!(max_sessions > 0, "SessionPool needs max_sessions >= 1");
        Self {
            plan,
            max_sessions: AtomicUsize::new(max_sessions),
            state: Mutex::new(PoolState { idle: Vec::new(), created: 0, checkouts: 0, waits: 0 }),
            cv: Condvar::new(),
            metrics: None,
        }
    }

    /// Like [`SessionPool::new`], publishing pool behavior to a metric
    /// registry as it runs.
    pub fn with_metrics(
        plan: Arc<FactorPlan>,
        max_sessions: usize,
        metrics: PoolMetrics,
    ) -> Self {
        metrics.target.set(max_sessions as f64);
        let mut pool = Self::new(plan, max_sessions);
        pool.metrics = Some(metrics);
        pool
    }

    /// The shared plan every pooled session factorizes against.
    pub fn plan(&self) -> &Arc<FactorPlan> {
        &self.plan
    }

    /// Upper bound on concurrently live sessions.
    pub fn max_sessions(&self) -> usize {
        self.max_sessions.load(Ordering::Acquire)
    }

    /// Retarget the session cap at runtime (autoscaler control knob).
    /// Growing wakes blocked checkouts so they can materialize new
    /// sessions immediately; shrinking frees excess **idle** sessions
    /// now and lets excess in-flight ones retire at checkin — a resize
    /// never cancels or blocks on running work.
    pub fn resize(&self, target: usize) {
        assert!(target > 0, "SessionPool needs max_sessions >= 1");
        let mut st = self.state.lock().unwrap();
        self.max_sessions.store(target, Ordering::Release);
        let mut retired = Vec::new();
        while st.created > target {
            match st.idle.pop() {
                Some(s) => {
                    st.created -= 1;
                    retired.push(s);
                }
                None => break, // the rest retire at checkin
            }
        }
        if let Some(m) = &self.metrics {
            m.target.set(target as f64);
            m.created.set(st.created as f64);
            m.in_use.set((st.created - st.idle.len()) as f64);
        }
        drop(st);
        drop(retired); // blocked-storage deallocation outside the lock
        self.cv.notify_all();
    }

    /// Check a session out, blocking if the pool is exhausted. The
    /// returned guard derefs to the session and checks it back in (and
    /// wakes one waiter) on drop.
    pub fn checkout(&self) -> PooledSession<'_> {
        let acquire_start = Instant::now();
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(s) = st.idle.pop() {
                st.checkouts += 1;
                self.note_checkout(&st, acquire_start);
                return PooledSession { pool: self, session: Some(s) };
            }
            if st.created < self.max_sessions() {
                st.created += 1;
                st.checkouts += 1;
                self.note_checkout(&st, acquire_start);
                drop(st); // allocate blocked storage outside the lock
                let s = SolverSession::from_plan(self.plan.clone());
                return PooledSession { pool: self, session: Some(s) };
            }
            st.waits += 1;
            if let Some(m) = &self.metrics {
                m.waits.inc();
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Bounded-wait checkout: like [`SessionPool::checkout`] but gives
    /// up once `timeout` has elapsed without a session becoming
    /// available, returning `None`. The serving drain path uses this so
    /// a stalled or leaked checkout elsewhere degrades into per-request
    /// [`crate::serve::ServeError::PoolTimeout`]s instead of a worker
    /// blocked forever.
    ///
    /// ```
    /// use sparselu::serve::SessionPool;
    /// use sparselu::session::FactorPlan;
    /// use sparselu::solver::SolveOptions;
    /// use sparselu::sparse::gen;
    /// use std::sync::Arc;
    /// use std::time::Duration;
    ///
    /// let a = gen::grid2d_laplacian(8, 8);
    /// let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
    /// let pool = SessionPool::new(plan, 1);
    ///
    /// let held = pool.checkout(); // pool (capacity 1) now exhausted
    /// let t = Duration::from_millis(10);
    /// assert!(pool.checkout_timeout(t).is_none(), "bounded wait, not a hang");
    /// drop(held);
    /// assert!(pool.checkout_timeout(t).is_some(), "idle again after checkin");
    /// ```
    pub fn checkout_timeout(&self, timeout: std::time::Duration) -> Option<PooledSession<'_>> {
        let acquire_start = Instant::now();
        let deadline = acquire_start + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(s) = st.idle.pop() {
                st.checkouts += 1;
                self.note_checkout(&st, acquire_start);
                return Some(PooledSession { pool: self, session: Some(s) });
            }
            if st.created < self.max_sessions() {
                st.created += 1;
                st.checkouts += 1;
                self.note_checkout(&st, acquire_start);
                drop(st); // allocate blocked storage outside the lock
                let s = SolverSession::from_plan(self.plan.clone());
                return Some(PooledSession { pool: self, session: Some(s) });
            }
            let now = Instant::now();
            let Some(remaining) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                return None;
            };
            st.waits += 1;
            if let Some(m) = &self.metrics {
                m.waits.inc();
            }
            let (guard, _timed_out) = self.cv.wait_timeout(st, remaining).unwrap();
            // loop re-checks idle/capacity either way: a timeout that
            // races a checkin still claims the session, and a spurious
            // wakeup re-arms with the remaining budget
            st = guard;
        }
    }

    /// Non-blocking checkout: `None` when the pool is exhausted.
    pub fn try_checkout(&self) -> Option<PooledSession<'_>> {
        let acquire_start = Instant::now();
        let mut st = self.state.lock().unwrap();
        if let Some(s) = st.idle.pop() {
            st.checkouts += 1;
            self.note_checkout(&st, acquire_start);
            return Some(PooledSession { pool: self, session: Some(s) });
        }
        if st.created < self.max_sessions() {
            st.created += 1;
            st.checkouts += 1;
            self.note_checkout(&st, acquire_start);
            drop(st);
            let s = SolverSession::from_plan(self.plan.clone());
            return Some(PooledSession { pool: self, session: Some(s) });
        }
        None
    }

    /// Current pool counters.
    pub fn stats(&self) -> PoolStats {
        let st = self.state.lock().unwrap();
        PoolStats {
            created: st.created,
            checkouts: st.checkouts,
            waits: st.waits,
            idle: st.idle.len(),
            in_use: st.created - st.idle.len(),
        }
    }

    /// Publish checkout-path metrics (called with the state lock held,
    /// after the counters were bumped).
    fn note_checkout(&self, st: &PoolState, acquire_start: Instant) {
        if let Some(m) = &self.metrics {
            m.checkouts.inc();
            m.checkout_wait.observe(acquire_start.elapsed().as_secs_f64());
            m.created.set(st.created as f64);
            m.in_use.set((st.created - st.idle.len()) as f64);
        }
    }

    fn checkin(&self, session: SolverSession<'static>) {
        let mut st = self.state.lock().unwrap();
        if st.created > self.max_sessions() {
            // the pool shrank while this session was out: retire it
            st.created -= 1;
        } else {
            st.idle.push(session);
        }
        if let Some(m) = &self.metrics {
            m.created.set(st.created as f64);
            m.in_use.set((st.created - st.idle.len()) as f64);
        }
        drop(st);
        self.cv.notify_one();
    }
}

/// RAII checkout guard: derefs to the pooled [`SolverSession`] and
/// returns it to the pool on drop (including on unwind, so a panicking
/// client cannot leak a session).
pub struct PooledSession<'p> {
    pool: &'p SessionPool,
    session: Option<SolverSession<'static>>,
}

impl Deref for PooledSession<'_> {
    type Target = SolverSession<'static>;
    fn deref(&self) -> &Self::Target {
        self.session.as_ref().expect("session present until drop")
    }
}

impl DerefMut for PooledSession<'_> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        self.session.as_mut().expect("session present until drop")
    }
}

impl Drop for PooledSession<'_> {
    fn drop(&mut self) {
        if let Some(s) = self.session.take() {
            self.pool.checkin(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolveOptions;
    use crate::sparse::gen;

    fn pool_for(max: usize) -> (crate::sparse::Csc, SessionPool) {
        let a = gen::grid2d_laplacian(8, 8);
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let pool = SessionPool::new(plan, max);
        (a, pool)
    }

    #[test]
    fn grows_lazily_and_reuses_returned_sessions() {
        let (a, pool) = pool_for(4);
        assert_eq!(pool.stats().created, 0, "no session before first checkout");
        {
            let mut s = pool.checkout();
            s.refactorize(&a.values).unwrap();
            assert_eq!(pool.stats().created, 1);
            assert_eq!(pool.stats().in_use, 1);
        }
        assert_eq!(pool.stats().idle, 1);
        // the second checkout reuses the returned session — its factors
        // (and refactor count) survive the round trip
        let s = pool.checkout();
        assert!(s.is_factored());
        assert_eq!(s.refactor_count(), 1);
        assert_eq!(pool.stats().created, 1, "no second allocation needed");
    }

    #[test]
    fn try_checkout_refuses_past_the_cap() {
        let (_, pool) = pool_for(2);
        let a = pool.try_checkout().expect("first session");
        let b = pool.try_checkout().expect("second session");
        assert!(pool.try_checkout().is_none(), "cap reached");
        drop(a);
        assert!(pool.try_checkout().is_some(), "checkin frees a slot");
        drop(b);
    }

    #[test]
    fn blocking_checkout_wakes_on_checkin() {
        let (a, pool) = pool_for(1);
        let mut first = pool.checkout();
        first.refactorize(&a.values).unwrap();
        std::thread::scope(|scope| {
            let pool = &pool;
            let waiter = scope.spawn(move || {
                let s = pool.checkout(); // blocks until `first` drops
                s.refactor_count()
            });
            // give the waiter time to block, then release
            std::thread::sleep(std::time::Duration::from_millis(20));
            drop(first);
            assert_eq!(waiter.join().unwrap(), 1, "waiter got the factored session");
        });
        // never more than one session materialized: the waiter was served
        // by the checkin, not by growth past the cap
        assert_eq!(pool.stats().created, 1);
        assert_eq!(pool.stats().checkouts, 2);
    }

    #[test]
    fn resize_grows_the_cap_and_wakes_waiters() {
        let (_, pool) = pool_for(1);
        let held = pool.checkout();
        assert!(pool.try_checkout().is_none(), "cap 1 exhausted");
        std::thread::scope(|scope| {
            let pool = &pool;
            let waiter = scope.spawn(move || {
                let _s = pool.checkout(); // blocks until the resize
                pool.stats().created
            });
            std::thread::sleep(std::time::Duration::from_millis(20));
            pool.resize(2); // grow: the waiter materializes session #2
            assert_eq!(waiter.join().unwrap(), 2);
        });
        drop(held);
        assert_eq!(pool.max_sessions(), 2);
    }

    #[test]
    fn shrink_retires_idle_now_and_in_flight_at_checkin() {
        let (_, pool) = pool_for(4);
        let a = pool.checkout();
        let b = pool.checkout();
        let c = pool.checkout();
        drop(c); // one idle, two in flight
        assert_eq!(pool.stats().created, 3);
        pool.resize(1);
        assert_eq!(pool.stats().created, 2, "the idle session retired immediately");
        assert_eq!(pool.stats().in_use, 2);
        drop(a); // created 2 > target 1: retired at checkin
        assert_eq!(pool.stats().created, 1);
        drop(b); // created 1 == target: kept
        let st = pool.stats();
        assert_eq!(st.created, 1);
        assert_eq!(st.idle, 1);
        // the survivor still serves
        assert!(pool.checkout().plan().n() > 0);
    }

    #[test]
    fn pool_metrics_track_occupancy_and_waits() {
        use crate::obs::Registry;
        let a = gen::grid2d_laplacian(8, 8);
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let registry = Registry::new();
        let m = PoolMetrics::register(&registry, &[("tenant", "t0")]);
        let pool = SessionPool::with_metrics(plan, 2, m);
        let s1 = pool.checkout();
        let s2 = pool.checkout();
        let gauge = |name: &str| registry.gauge(name, "", &[("tenant", "t0")]).get();
        assert_eq!(gauge("sparselu_pool_sessions_in_use"), 2.0);
        assert_eq!(gauge("sparselu_pool_sessions_created"), 2.0);
        assert_eq!(gauge("sparselu_pool_sessions_target"), 2.0);
        drop(s1);
        drop(s2);
        assert_eq!(gauge("sparselu_pool_sessions_in_use"), 0.0);
        let checkouts =
            registry.counter("sparselu_pool_checkouts_total", "", &[("tenant", "t0")]);
        assert_eq!(checkouts.get(), 2);
        let wait_hist = registry.histogram(
            "sparselu_pool_checkout_wait_seconds",
            "",
            &[("tenant", "t0")],
            &crate::obs::LATENCY_BUCKETS,
        );
        assert_eq!(wait_hist.snapshot().count(), 2, "one wait observation per checkout");
    }

    #[test]
    fn pooled_sessions_share_the_one_plan() {
        let (_, pool) = pool_for(3);
        let s1 = pool.checkout();
        let s2 = pool.checkout();
        assert!(Arc::ptr_eq(s1.plan(), pool.plan()));
        assert!(Arc::ptr_eq(s1.plan(), s2.plan()));
    }
}
