//! [`Router`] — the multi-tenant front-end: route every request to the
//! shard owning its **sparsity pattern**, and drain shards concurrently
//! so tenants never serialize against each other.
//!
//! The paper's plan/execute split makes the *pattern* the natural unit
//! of tenancy: everything expensive (ordering, symbolic analysis,
//! irregular blocking, DAG construction) is per-pattern and immutable,
//! while per-request work is numeric-only. The task-queue solver
//! literature (asynchronous fan-both Cholesky, 2D partitioned-block
//! task parallelism) routes *tasks* by structure rather than by arrival
//! order to keep parallelism fed; the router applies the same idea one
//! level up, routing whole requests by pattern fingerprint:
//!
//! * **Admission** — [`Router::admit`] fingerprints a matrix
//!   ([`crate::sparse::Csc::pattern_fingerprint`] mixed with the solve
//!   options, i.e. [`PlanCache::key_for`]) and lazily spins up a
//!   *shard*: one `Arc<FactorPlan>` resolved through the shared
//!   [`PlanCache`] (warmable from disk via [`crate::serve::persist`]),
//!   one [`SessionPool`], one [`Batcher`]. Re-admitting a known pattern
//!   is a cheap LRU touch; re-admitting an evicted one *revives* it —
//!   usually from the still-cached plan, else from disk, else rebuilt.
//! * **Routing** — [`Router::submit`] enqueues onto the tenant's
//!   bounded shard queue; a full queue is a clean
//!   [`ServeError::ShardFull`] back to that client, never backpressure
//!   on anyone else's tenant.
//! * **Execution** — [`Router::drain_all`] walks the live shards with a
//!   worker pool: each shard is drained by exactly one worker at a time
//!   (per-tenant requests keep their submission order, which is what
//!   makes timestep streams and change-set batching sound), while
//!   different tenants factorize concurrently on their own sessions.
//! * **Eviction** — when the shard table is full, the victim is the
//!   least-recently-used *idle* shard, using the [`PlanCache`]'s own
//!   LRU order ([`PlanCache::keys_lru`]) as the source of truth — a
//!   shard whose plan the cache already dropped is the most evictable
//!   of all. Shards with queued or in-flight work are never evicted;
//!   if every shard is busy, admission fails with
//!   [`ServeError::RouterFull`].
//!
//! ## Serving two netlists at once
//!
//! ```
//! use sparselu::serve::{Request, Router, RouterConfig};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//!
//! let router = Router::new(SolveOptions::ours(1), RouterConfig::default());
//! let a = gen::grid2d_laplacian(8, 8);
//! let b = gen::grid2d_laplacian(8, 9); // a different sparsity pattern
//! let ta = router.admit(&a).unwrap();  // spins the shard up (plan built once)
//! let tb = router.admit(&b).unwrap();
//! assert_ne!(ta, tb, "distinct patterns get distinct tenants");
//!
//! router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
//! router.submit(ta, Request::Solve { rhs: vec![1.0; a.n_rows()] }).unwrap();
//! router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
//!
//! // both tenants drain concurrently on the worker pool
//! let drained = router.drain_all(2);
//! assert_eq!(drained.len(), 2);
//! for (_tenant, outcomes) in &drained {
//!     assert!(outcomes.iter().all(|o| o.is_ok()));
//! }
//! ```

use super::batcher::{Batcher, Request, RequestKind, ServeError, ServeReport};
use super::persist;
use super::pool::SessionPool;
use crate::session::{FactorPlan, PlanCache};
use crate::solver::SolveOptions;
use crate::sparse::Csc;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Stable identity of one tenant: the [`PlanCache`] key of its sparsity
/// pattern under the router's solve options. The id survives eviction —
/// re-admitting the same pattern yields the same id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TenantId(pub u64);

/// Router sizing and policy.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Maximum live shards (tenants with materialized sessions). Beyond
    /// this, admitting a new pattern evicts the LRU idle shard.
    pub max_shards: usize,
    /// Capacity of the shared [`PlanCache`]. Sized above `max_shards`
    /// so an evicted shard's plan usually survives for a cheap revival.
    pub plan_cache_capacity: usize,
    /// Bound of each shard's request queue (admission control:
    /// [`ServeError::ShardFull`] past it).
    pub shard_queue: usize,
    /// Session cap of each shard's [`SessionPool`]. Shard drains are
    /// serialized per tenant, so one warm session per shard is the
    /// steady state; the cap only bounds transient overlap (e.g. a
    /// drain racing a snapshot taken just before an eviction).
    pub sessions_per_shard: usize,
    /// Stamp routing threshold forwarded to each shard's [`Batcher`].
    pub partial_threshold: f64,
    /// Change-set batching across timesteps, forwarded to each shard's
    /// [`Batcher`].
    pub coalesce_stamps: bool,
    /// When set: warm the plan cache from this directory at startup and
    /// persist every freshly built plan into it (best-effort — IO
    /// failures degrade to cold builds, they never fail serving).
    pub plan_dir: Option<PathBuf>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_shards: 8,
            plan_cache_capacity: 16,
            shard_queue: 64,
            sessions_per_shard: 1,
            partial_threshold: 0.5,
            coalesce_stamps: true,
            plan_dir: None,
        }
    }
}

/// Cumulative per-tenant serving metrics, aggregated from every
/// [`ServeReport`] the tenant's shard produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    /// Requests accepted into the shard queue.
    pub submitted: usize,
    /// Requests rejected at admission ([`ServeError::ShardFull`]).
    pub rejected: usize,
    /// Requests executed successfully.
    pub completed: usize,
    /// Requests that executed but returned an error to the client.
    pub errored: usize,
    /// Completed requests by kind.
    pub solves: usize,
    pub stamps: usize,
    pub fulls: usize,
    /// DAG tasks executed / skipped on this tenant's behalf (coalesced
    /// runs counted once — see [`ServeReport::tasks_executed`]).
    pub tasks_executed: usize,
    pub tasks_skipped: usize,
    /// Summed per-request queue wait and execution seconds.
    pub queue_seconds: f64,
    pub exec_seconds: f64,
}

impl TenantStats {
    fn absorb(&mut self, outcomes: &[Result<ServeReport, ServeError>]) {
        for outcome in outcomes {
            match outcome {
                Ok(rep) => {
                    self.completed += 1;
                    match rep.kind {
                        RequestKind::Solve => self.solves += 1,
                        RequestKind::Stamp => self.stamps += 1,
                        RequestKind::Refactorize => self.fulls += 1,
                    }
                    self.tasks_executed += rep.tasks_executed;
                    self.tasks_skipped += rep.tasks_skipped;
                    self.queue_seconds += rep.queue_seconds;
                    self.exec_seconds += rep.exec_seconds;
                }
                Err(_) => self.errored += 1,
            }
        }
    }
}

/// Router-level counters.
#[derive(Clone, Copy, Debug)]
pub struct RouterStats {
    /// Shards currently live.
    pub shards_live: usize,
    /// Shards spun up over the router's lifetime (first admissions plus
    /// revivals).
    pub spin_ups: usize,
    /// Shards evicted to make room.
    pub evictions: usize,
    /// Evicted tenants spun up again.
    pub revivals: usize,
    /// Plan files warm-loaded from `plan_dir` at startup.
    pub plans_warmed: usize,
    /// Shared plan-cache counters.
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// One tenant's serving state: the immutable plan plus this pattern's
/// mutable serving machinery. Everything mutable is behind its own lock,
/// so shards never contend with each other.
struct Shard {
    tenant: TenantId,
    plan: Arc<FactorPlan>,
    pool: SessionPool,
    batcher: Mutex<Batcher>,
    stats: Mutex<TenantStats>,
    /// Set (under the batcher lock, with the queue verified empty) when
    /// the shard is evicted. A submit that looked the shard up *before*
    /// the eviction but enqueues *after* would otherwise land its
    /// request on an orphaned queue nobody will ever drain; checking
    /// this flag under the same lock closes that window.
    retired: AtomicBool,
}

impl Shard {
    /// Execute everything queued on this shard. The batcher lock is held
    /// for the duration, serializing drains *within* the tenant — which
    /// is exactly the per-tenant total order timestep streams need —
    /// while other shards drain in parallel on their own locks.
    fn drain(&self) -> Vec<Result<ServeReport, ServeError>> {
        let mut batcher = self.batcher.lock().unwrap();
        if batcher.is_empty() {
            return Vec::new();
        }
        // LIFO checkout hands back the warm session holding this
        // tenant's current factors; serialized drains mean the pool
        // never blocks here
        let mut session = self.pool.checkout();
        let outcomes = batcher.drain(&mut session);
        drop(session);
        drop(batcher);
        self.stats.lock().unwrap().absorb(&outcomes);
        outcomes
    }
}

struct RouterState {
    cache: PlanCache,
    /// Live shards, least-recently-touched first (admission/submission
    /// order — kept in lockstep with the cache via [`PlanCache::touch`]).
    shards: Vec<Arc<Shard>>,
    /// Tenants that once had a shard and were evicted (for the revival
    /// counter).
    evicted: HashSet<u64>,
    spin_ups: usize,
    evictions: usize,
    revivals: usize,
    plans_warmed: usize,
}

/// Multi-tenant serving front-end over pattern-keyed shards. See the
/// [module docs](self) for the full story.
pub struct Router {
    cfg: RouterConfig,
    opts: SolveOptions,
    state: Mutex<RouterState>,
}

impl Router {
    /// Router serving every tenant under one set of solve options. If
    /// `cfg.plan_dir` is set, the plan cache is warmed from it now
    /// (best-effort: unreadable files are skipped, a missing directory
    /// is created).
    pub fn new(opts: SolveOptions, cfg: RouterConfig) -> Self {
        assert!(cfg.max_shards > 0, "Router needs max_shards >= 1");
        assert!(cfg.plan_cache_capacity >= cfg.max_shards, "cache must cover the live shards");
        let mut cache = PlanCache::new(cfg.plan_cache_capacity);
        let mut plans_warmed = 0;
        if let Some(dir) = &cfg.plan_dir {
            if let Err(e) = std::fs::create_dir_all(dir) {
                eprintln!("router: cannot create plan dir {}: {e}", dir.display());
            } else {
                match cache.warm_from_dir(dir) {
                    Ok(warm) => {
                        plans_warmed = warm.loaded;
                        for (path, err) in &warm.skipped {
                            eprintln!("router: skipped plan file {}: {err}", path.display());
                        }
                    }
                    Err(e) => eprintln!("router: warming from {} failed: {e}", dir.display()),
                }
            }
        }
        Self {
            cfg,
            opts,
            state: Mutex::new(RouterState {
                cache,
                shards: Vec::new(),
                evicted: HashSet::new(),
                spin_ups: 0,
                evictions: 0,
                revivals: 0,
                plans_warmed,
            }),
        }
    }

    /// Solve options every tenant is served under.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// The tenant id `a`'s pattern routes to (no shard is created).
    pub fn tenant_of(&self, a: &Csc) -> TenantId {
        TenantId(PlanCache::key_for(a, &self.opts))
    }

    /// Admit a matrix's sparsity pattern: return its tenant id, spinning
    /// a shard up if none is live. The plan is resolved through the
    /// shared cache (hit, disk-warmed file, or cold build — in that
    /// order of cost); freshly built plans are persisted to `plan_dir`
    /// when configured.
    ///
    /// Fails with [`ServeError::RouterFull`] when the shard table is at
    /// capacity and every live shard has queued or in-flight work.
    pub fn admit(&self, a: &Csc) -> Result<TenantId, ServeError> {
        let tenant = self.tenant_of(a);
        let mut st = self.state.lock().unwrap();
        if let Some(pos) = st.shards.iter().position(|s| s.tenant == tenant) {
            let shard = st.shards.remove(pos);
            st.shards.push(shard);
            st.cache.touch(tenant.0);
            return Ok(tenant);
        }
        if st.shards.len() == self.cfg.max_shards {
            self.evict_locked(&mut st)?;
        }
        let misses_before = st.cache.misses();
        let plan = st.cache.get_or_build(a, &self.opts);
        if st.cache.misses() > misses_before {
            if let Some(dir) = &self.cfg.plan_dir {
                if let Err(e) = persist::save_plan_to_dir(&plan, dir) {
                    eprintln!("router: persisting plan to {} failed: {e}", dir.display());
                }
            }
        }
        let batcher = Batcher::new(self.cfg.shard_queue)
            .with_partial_threshold(self.cfg.partial_threshold)
            .with_stamp_coalescing(self.cfg.coalesce_stamps);
        let shard = Arc::new(Shard {
            tenant,
            pool: SessionPool::new(plan.clone(), self.cfg.sessions_per_shard),
            plan,
            batcher: Mutex::new(batcher),
            stats: Mutex::new(TenantStats::default()),
            retired: AtomicBool::new(false),
        });
        st.shards.push(shard);
        st.spin_ups += 1;
        if st.evicted.remove(&tenant.0) {
            st.revivals += 1;
        }
        Ok(tenant)
    }

    /// Evict the least-recently-used **idle** shard (empty queue, no
    /// session checked out), ranking idleness by the plan cache's own
    /// LRU order: a shard whose plan the cache already evicted ranks
    /// before everything still cached. Busy shards are never evicted.
    fn evict_locked(&self, st: &mut RouterState) -> Result<(), ServeError> {
        let order = st.cache.keys_lru();
        let rank = |key: u64| -> i64 {
            order.iter().position(|&k| k == key).map_or(-1, |p| p as i64)
        };
        // pass 1: rank the currently idle shards (try_lock: a held
        // batcher lock means a drain is in flight — that shard is busy)
        let mut candidates: Vec<(usize, i64)> = st
            .shards
            .iter()
            .enumerate()
            .filter_map(|(i, shard)| {
                let queue_empty = match shard.batcher.try_lock() {
                    Ok(b) => b.is_empty(),
                    Err(_) => false,
                };
                if queue_empty && shard.pool.stats().in_use == 0 {
                    Some((i, rank(shard.tenant.0)))
                } else {
                    None
                }
            })
            .collect();
        candidates.sort_by_key(|&(_, r)| r);
        // pass 2: retire the best candidate that is *still* idle under
        // its batcher lock. Setting `retired` with the queue verified
        // empty under that lock means a racing submit (which looked the
        // shard up before we removed it) either already enqueued — and
        // we see the queue non-empty and skip — or will see the flag
        // and get UnknownTenant. No accepted request is ever orphaned.
        for (pos, _) in candidates {
            let shard = &st.shards[pos];
            let guard = shard.batcher.lock().unwrap();
            if !guard.is_empty() || shard.pool.stats().in_use != 0 {
                continue;
            }
            shard.retired.store(true, Ordering::Release);
            drop(guard);
            let shard = st.shards.remove(pos);
            st.evicted.insert(shard.tenant.0);
            st.evictions += 1;
            // the plan itself stays in the cache under its own LRU life
            // — revival is a cache hit until the cache too moves on
            return Ok(());
        }
        Err(ServeError::RouterFull { max_shards: self.cfg.max_shards })
    }

    /// Clone the live shard for `tenant`, refreshing its recency (both
    /// in the shard table and the plan cache).
    fn shard_of(&self, tenant: TenantId) -> Result<Arc<Shard>, ServeError> {
        let mut st = self.state.lock().unwrap();
        let Some(pos) = st.shards.iter().position(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        let shard = st.shards.remove(pos);
        st.shards.push(shard.clone());
        st.cache.touch(tenant.0);
        Ok(shard)
    }

    /// Enqueue a request on its tenant's shard. A full shard queue comes
    /// back as [`ServeError::ShardFull`] — backpressure scoped to this
    /// tenant alone.
    pub fn submit(&self, tenant: TenantId, request: Request) -> Result<(), ServeError> {
        let shard = self.shard_of(tenant)?;
        let mut batcher = shard.batcher.lock().unwrap();
        // the shard may have been evicted between the lookup above and
        // taking its lock; the flag is only ever set under this lock, so
        // checking it here guarantees an accepted request lands on a
        // queue that will still be drained
        if shard.retired.load(Ordering::Acquire) {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        }
        let result = batcher.submit(request);
        drop(batcher);
        let mut stats = shard.stats.lock().unwrap();
        match result {
            Ok(()) => {
                stats.submitted += 1;
                Ok(())
            }
            Err(ServeError::QueueFull { capacity }) => {
                stats.rejected += 1;
                Err(ServeError::ShardFull { tenant: tenant.0, capacity })
            }
            // Batcher::submit only rejects on a full queue today; pass
            // anything future through untouched (it is not an admission
            // rejection, so it does not count as one)
            Err(other) => Err(other),
        }
    }

    /// Drain one tenant's queue, returning its outcomes in submission
    /// order.
    pub fn drain_tenant(
        &self,
        tenant: TenantId,
    ) -> Result<Vec<Result<ServeReport, ServeError>>, ServeError> {
        Ok(self.shard_of(tenant)?.drain())
    }

    /// Drain every live shard on a pool of `workers` threads. Each shard
    /// is drained by exactly one worker (per-tenant order preserved);
    /// distinct tenants execute concurrently. Returns the non-empty
    /// outcome groups, one per tenant that had queued work.
    pub fn drain_all(
        &self,
        workers: usize,
    ) -> Vec<(TenantId, Vec<Result<ServeReport, ServeError>>)> {
        let shards: Vec<Arc<Shard>> = self.state.lock().unwrap().shards.clone();
        if shards.is_empty() {
            return Vec::new();
        }
        let workers = workers.clamp(1, shards.len());
        let next = AtomicUsize::new(0);
        let mut grouped: Vec<(TenantId, Vec<Result<ServeReport, ServeError>>)> =
            shards.iter().map(|s| (s.tenant, Vec::new())).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let (next, shards) = (&next, &shards);
                    scope.spawn(move || {
                        let mut drained = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= shards.len() {
                                break;
                            }
                            let outcomes = shards[i].drain();
                            if !outcomes.is_empty() {
                                drained.push((i, outcomes));
                            }
                        }
                        drained
                    })
                })
                .collect();
            for handle in handles {
                for (i, outcomes) in handle.join().expect("drain worker panicked") {
                    grouped[i].1 = outcomes;
                }
            }
        });
        grouped.retain(|(_, outcomes)| !outcomes.is_empty());
        grouped
    }

    /// Queued (undrained) requests on a tenant's shard.
    pub fn queued(&self, tenant: TenantId) -> Result<usize, ServeError> {
        let st = self.state.lock().unwrap();
        let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        Ok(shard.batcher.lock().unwrap().len())
    }

    /// The plan a tenant's shard serves against.
    pub fn plan_of(&self, tenant: TenantId) -> Result<Arc<FactorPlan>, ServeError> {
        let st = self.state.lock().unwrap();
        let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        Ok(shard.plan.clone())
    }

    /// Cumulative metrics of one tenant (read-only: does not touch LRU
    /// recency).
    pub fn tenant_stats(&self, tenant: TenantId) -> Result<TenantStats, ServeError> {
        let st = self.state.lock().unwrap();
        let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        let stats = *shard.stats.lock().unwrap();
        Ok(stats)
    }

    /// Live tenants, least-recently-touched first.
    pub fn tenants(&self) -> Vec<TenantId> {
        self.state.lock().unwrap().shards.iter().map(|s| s.tenant).collect()
    }

    /// Router-level counters.
    pub fn stats(&self) -> RouterStats {
        let st = self.state.lock().unwrap();
        RouterStats {
            shards_live: st.shards.len(),
            spin_ups: st.spin_ups,
            evictions: st.evictions,
            revivals: st.revivals,
            plans_warmed: st.plans_warmed,
            cache_hits: st.cache.hits(),
            cache_misses: st.cache.misses(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn small_router(max_shards: usize, shard_queue: usize) -> Router {
        Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards,
                plan_cache_capacity: max_shards.max(2) * 2,
                shard_queue,
                ..RouterConfig::default()
            },
        )
    }

    #[test]
    fn admit_routes_same_pattern_to_same_tenant() {
        let router = small_router(4, 8);
        let a = gen::grid2d_laplacian(6, 6);
        let t1 = router.admit(&a).unwrap();
        // same pattern, different values: same tenant, no new shard
        let mut a2 = a.clone();
        for v in &mut a2.values {
            *v *= 2.0;
        }
        let t2 = router.admit(&a2).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(router.stats().spin_ups, 1);
        assert_eq!(router.stats().shards_live, 1);
        assert_eq!(router.tenant_of(&a), t1);
        // a different pattern gets its own shard
        let b = gen::grid2d_laplacian(6, 7);
        let t3 = router.admit(&b).unwrap();
        assert_ne!(t1, t3);
        assert_eq!(router.stats().shards_live, 2);
    }

    #[test]
    fn submit_to_unknown_tenant_is_a_clean_error() {
        let router = small_router(2, 4);
        let bogus = TenantId(0x1234);
        assert!(matches!(
            router.submit(bogus, Request::Solve { rhs: vec![1.0] }),
            Err(ServeError::UnknownTenant { tenant: 0x1234 })
        ));
        assert!(matches!(
            router.drain_tenant(bogus),
            Err(ServeError::UnknownTenant { .. })
        ));
    }

    #[test]
    fn full_shard_rejects_with_shard_full_and_counts_it() {
        let router = small_router(2, 2);
        let a = gen::grid2d_laplacian(6, 6);
        let t = router.admit(&a).unwrap();
        let rhs = vec![1.0; a.n_rows()];
        router.submit(t, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap();
        let err = router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap_err();
        assert!(matches!(err, ServeError::ShardFull { capacity: 2, .. }));
        assert_eq!(router.queued(t).unwrap(), 2);
        // draining frees the queue; the rejection was counted per-tenant
        let outcomes = router.drain_tenant(t).unwrap();
        assert_eq!(outcomes.len(), 2);
        router.submit(t, Request::Solve { rhs }).unwrap();
        let stats = router.tenant_stats(t).unwrap();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn eviction_prefers_cache_lru_and_spares_busy_shards() {
        let router = small_router(2, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let c = gen::grid2d_laplacian(7, 7);
        let ta = router.admit(&a).unwrap();
        let tb = router.admit(&b).unwrap();
        // `a` is LRU but busy (queued work); `b` is idle → b is evicted
        router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
        let tc = router.admit(&c).unwrap();
        let stats = router.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.shards_live, 2);
        let live = router.tenants();
        assert!(live.contains(&ta), "busy shard spared");
        assert!(live.contains(&tc));
        assert!(!live.contains(&tb), "idle LRU shard evicted");
        // the busy shard's queued work still drains fine
        let outcomes = router.drain_tenant(ta).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].is_ok());
    }

    #[test]
    fn router_full_when_every_shard_is_busy() {
        let router = small_router(2, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let ta = router.admit(&a).unwrap();
        let tb = router.admit(&b).unwrap();
        router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
        let c = gen::grid2d_laplacian(7, 7);
        assert!(matches!(
            router.admit(&c),
            Err(ServeError::RouterFull { max_shards: 2 })
        ));
        // draining any shard makes room again
        router.drain_tenant(ta).unwrap();
        assert!(router.admit(&c).is_ok());
    }

    #[test]
    fn revived_tenant_reuses_the_cached_plan() {
        let router = small_router(1, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let ta = router.admit(&a).unwrap();
        let plan_a = router.plan_of(ta).unwrap();
        router.admit(&b).unwrap(); // evicts a's shard (cap 1)
        assert!(matches!(
            router.submit(ta, Request::Solve { rhs: vec![1.0; 36] }),
            Err(ServeError::UnknownTenant { .. })
        ));
        let misses_before = router.stats().cache_misses;
        let ta2 = router.admit(&a).unwrap(); // revival
        assert_eq!(ta, ta2, "tenant id is stable across eviction");
        let stats = router.stats();
        assert_eq!(stats.revivals, 1);
        assert_eq!(stats.cache_misses, misses_before, "revival hit the plan cache");
        assert!(
            Arc::ptr_eq(&plan_a, &router.plan_of(ta2).unwrap()),
            "the revived shard shares the original plan"
        );
    }
}
