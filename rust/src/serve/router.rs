//! [`Router`] — the multi-tenant front-end: route every request to the
//! shard owning its **sparsity pattern**, and drain shards concurrently
//! so tenants never serialize against each other.
//!
//! The paper's plan/execute split makes the *pattern* the natural unit
//! of tenancy: everything expensive (ordering, symbolic analysis,
//! irregular blocking, DAG construction) is per-pattern and immutable,
//! while per-request work is numeric-only. The task-queue solver
//! literature (asynchronous fan-both Cholesky, 2D partitioned-block
//! task parallelism) routes *tasks* by structure rather than by arrival
//! order to keep parallelism fed; the router applies the same idea one
//! level up, routing whole requests by pattern fingerprint:
//!
//! * **Admission** — [`Router::admit`] fingerprints a matrix
//!   ([`crate::sparse::Csc::pattern_fingerprint`] mixed with the solve
//!   options, i.e. [`PlanCache::key_for`]) and lazily spins up a
//!   *shard*: one `Arc<FactorPlan>` resolved through the shared
//!   [`PlanCache`] (warmable from disk via [`crate::serve::persist`]),
//!   one [`SessionPool`], one [`Batcher`]. Re-admitting a known pattern
//!   is a cheap LRU touch; re-admitting an evicted one *revives* it —
//!   usually from the still-cached plan, else from disk, else rebuilt.
//! * **Routing** — [`Router::submit`] enqueues onto the tenant's
//!   bounded shard queue; a full queue is a clean
//!   [`ServeError::ShardFull`] back to that client, never backpressure
//!   on anyone else's tenant.
//! * **Execution** — [`Router::drain_all`] walks the live shards with a
//!   worker pool: each shard is drained by exactly one worker at a time
//!   (per-tenant requests keep their submission order, which is what
//!   makes timestep streams and change-set batching sound), while
//!   different tenants factorize concurrently on their own sessions.
//! * **Eviction** — when the shard table is full, the victim is the
//!   least-recently-used *idle* shard, using the [`PlanCache`]'s own
//!   LRU order ([`PlanCache::keys_lru`]) as the source of truth — a
//!   shard whose plan the cache already dropped is the most evictable
//!   of all. Shards with queued or in-flight work are never evicted;
//!   if every shard is busy, admission fails with
//!   [`ServeError::RouterFull`].
//!
//! ## Serving two netlists at once
//!
//! ```
//! use sparselu::serve::{Request, Router, RouterConfig};
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//!
//! let router = Router::new(SolveOptions::ours(1), RouterConfig::default());
//! let a = gen::grid2d_laplacian(8, 8);
//! let b = gen::grid2d_laplacian(8, 9); // a different sparsity pattern
//! let ta = router.admit(&a).unwrap();  // spins the shard up (plan built once)
//! let tb = router.admit(&b).unwrap();
//! assert_ne!(ta, tb, "distinct patterns get distinct tenants");
//!
//! router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
//! router.submit(ta, Request::Solve { rhs: vec![1.0; a.n_rows()] }).unwrap();
//! router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
//!
//! // both tenants drain concurrently on the worker pool
//! let drained = router.drain_all(2);
//! assert_eq!(drained.len(), 2);
//! for (_tenant, outcomes) in &drained {
//!     assert!(outcomes.iter().all(|o| o.is_ok()));
//! }
//! ```

use super::batcher::{Batcher, Priority, Request, RequestKind, ServeError, ServeReport};
use super::persist;
use super::pool::{PoolMetrics, SessionPool};
use crate::coordinator::Executor;
use crate::numeric::factor::FactorError;
use crate::numeric::Precision;
use crate::obs::{self, Counter, Gauge, Histogram, HistogramSnapshot, Registry};
use crate::session::{ChangeSet, FactorPlan, PlanCache, PlanReport, SharedPlanCache};
use crate::solver::SolveOptions;
use crate::sparse::Csc;
use std::collections::HashSet;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock, RwLock};
use std::time::{Duration, Instant};

/// Stable identity of one tenant: the [`PlanCache`] key of its sparsity
/// pattern under the router's solve options. The id survives eviction —
/// re-admitting the same pattern yields the same id.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TenantId(pub u64);

/// Router sizing and policy.
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Maximum live shards (tenants with materialized sessions). Beyond
    /// this, admitting a new pattern evicts the LRU idle shard.
    pub max_shards: usize,
    /// Capacity of the shared [`PlanCache`]. Sized above `max_shards`
    /// so an evicted shard's plan usually survives for a cheap revival.
    pub plan_cache_capacity: usize,
    /// Bound of each shard's request queue (admission control:
    /// [`ServeError::ShardFull`] past it).
    pub shard_queue: usize,
    /// Session cap of each shard's [`SessionPool`]. Shard drains are
    /// serialized per tenant, so one warm session per shard is the
    /// steady state; the cap only bounds transient overlap (e.g. a
    /// drain racing a snapshot taken just before an eviction).
    pub sessions_per_shard: usize,
    /// Stamp routing threshold forwarded to each shard's [`Batcher`].
    pub partial_threshold: f64,
    /// Change-set batching across timesteps, forwarded to each shard's
    /// [`Batcher`].
    pub coalesce_stamps: bool,
    /// Factorization precision every shard serves at, forwarded to each
    /// shard's [`Batcher`]. Under [`Precision::Mixed`] refactorizes and
    /// stamps run the f32 kernels and clients solve via
    /// [`Request::SolveMixed`] (f64 accuracy recovered by iterative
    /// refinement); plain solves are rejected with
    /// [`ServeError::PrecisionMismatch`].
    pub precision: Precision,
    /// Consecutive out-of-pattern stamps from one tenant before
    /// [`Router::submit_stamp_coords`] treats the drift as a storm and
    /// spins the drifted pattern up in the background
    /// ([`Router::admit_background`]). Below the threshold each drifted
    /// stamp is rejected with [`ServeError::PatternDrift`].
    pub drift_storm_threshold: usize,
    /// When set: warm the plan cache from this directory at startup and
    /// persist every freshly built plan into it (best-effort — IO
    /// failures degrade to cold builds, they never fail serving).
    pub plan_dir: Option<PathBuf>,
    /// When set, a drain that cannot check a session out of the
    /// tenant's pool within this long fails that drain's queued
    /// requests with [`ServeError::PoolTimeout`] instead of blocking
    /// the drain worker indefinitely (a stalled or leaked session
    /// then costs one tenant latency, never the whole drain pool).
    /// `None` (the default) blocks as long as it takes.
    pub checkout_timeout: Option<Duration>,
    /// Metric registry the router (and everything under it: per-tenant
    /// shards, session pools, the shared executor) publishes to.
    /// `None` routes to the process-wide [`Registry::global`]; tests
    /// and scoped benches pass their own for isolated scrapes.
    pub registry: Option<Arc<Registry>>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        Self {
            max_shards: 8,
            plan_cache_capacity: 16,
            shard_queue: 64,
            sessions_per_shard: 1,
            partial_threshold: 0.5,
            coalesce_stamps: true,
            precision: Precision::Full,
            drift_storm_threshold: 3,
            plan_dir: None,
            checkout_timeout: None,
            registry: None,
        }
    }
}

/// Cumulative per-tenant serving metrics, aggregated from every
/// [`ServeReport`] the tenant's shard produced.
#[derive(Clone, Copy, Debug, Default)]
pub struct TenantStats {
    /// Requests accepted into the shard queue.
    pub submitted: usize,
    /// Requests rejected at admission ([`ServeError::ShardFull`]).
    pub rejected: usize,
    /// Requests executed successfully.
    pub completed: usize,
    /// Requests that executed but returned an error to the client.
    pub errored: usize,
    /// Completed requests by kind.
    pub solves: usize,
    pub mixed_solves: usize,
    pub stamps: usize,
    pub fulls: usize,
    /// Summed refinement corrections across completed mixed solves
    /// (`mixed_solves` divides this into a mean).
    pub refine_iterations: usize,
    /// DAG tasks executed / skipped on this tenant's behalf (coalesced
    /// runs counted once — see [`ServeReport::tasks_executed`]).
    pub tasks_executed: usize,
    pub tasks_skipped: usize,
    /// Summed per-request queue wait and execution seconds.
    pub queue_seconds: f64,
    pub exec_seconds: f64,
    /// Completed requests served degraded (mixed→full fallback or
    /// partial→full retry — see [`ServeReport::degraded`]).
    pub degraded: usize,
}

impl TenantStats {
    fn absorb(&mut self, outcomes: &[Result<ServeReport, ServeError>]) {
        for outcome in outcomes {
            match outcome {
                Ok(rep) => {
                    self.completed += 1;
                    match rep.kind {
                        RequestKind::Solve => self.solves += 1,
                        RequestKind::SolveMixed => self.mixed_solves += 1,
                        RequestKind::Stamp => self.stamps += 1,
                        RequestKind::Refactorize => self.fulls += 1,
                    }
                    self.refine_iterations += rep.refine_iterations.unwrap_or(0);
                    self.tasks_executed += rep.tasks_executed;
                    self.tasks_skipped += rep.tasks_skipped;
                    self.queue_seconds += rep.queue_seconds;
                    self.exec_seconds += rep.exec_seconds;
                    self.degraded += rep.degraded as usize;
                }
                Err(_) => self.errored += 1,
            }
        }
    }
}

/// Router-level counters.
#[derive(Clone, Copy, Debug)]
pub struct RouterStats {
    /// Shards currently live.
    pub shards_live: usize,
    /// Shards spun up over the router's lifetime (first admissions plus
    /// revivals).
    pub spin_ups: usize,
    /// Shards evicted to make room.
    pub evictions: usize,
    /// Evicted tenants spun up again.
    pub revivals: usize,
    /// Plan files warm-loaded from `plan_dir` at startup.
    pub plans_warmed: usize,
    /// Corrupt or unreadable plan files skipped during the warm pass.
    pub plans_warm_skipped: usize,
    /// Background plan builds kicked off by drift storms
    /// ([`Router::admit_background`]).
    pub speculative_builds: usize,
    /// Shared plan-cache counters.
    pub cache_hits: usize,
    pub cache_misses: usize,
}

/// Point-in-time health of one live shard — what the
/// [`crate::obs::autoscale`] control loop reads each tick.
#[derive(Clone, Debug)]
pub struct TenantHealth {
    /// The shard's tenant.
    pub tenant: TenantId,
    /// Requests queued right now.
    pub queue_depth: usize,
    /// Current queue bound.
    pub queue_capacity: usize,
    /// Current [`Priority::Low`] admission watermark
    /// (`== queue_capacity` when shedding is off).
    pub low_priority_limit: usize,
    /// Current session cap (the autoscaler's resize target).
    pub sessions_target: usize,
    /// Sessions materialized.
    pub sessions_created: usize,
    /// Sessions checked out right now.
    pub sessions_in_use: usize,
    /// Cumulative queue-wait histogram; delta two readings for the
    /// interval distribution (see
    /// [`HistogramSnapshot::delta`]).
    pub queue_wait: HistogramSnapshot,
    /// Whether the shard is currently quarantined (failing fast with
    /// [`ServeError::TenantQuarantined`] while its pool rebuilds).
    pub quarantined: bool,
    /// Cumulative quarantine trips.
    pub quarantines: usize,
    /// Quarantines lifted by a successful background pool rebuild.
    pub quarantine_revivals: usize,
}

/// Registry handles for the router-level series, created once in
/// [`Router::new`] and updated eagerly at each mutation point (no
/// render-time callback — so there is no lock-order coupling between
/// the registry and the router state).
struct RouterMetrics {
    shards_live: Gauge,
    spin_ups: Counter,
    evictions: Counter,
    revivals: Counter,
    plans_warmed: Counter,
    warm_skipped: Counter,
    speculative_builds: Counter,
    pattern_drifts: Counter,
    cache_hits: Counter,
    cache_misses: Counter,
    plan_build: PlanBuildPhases,
    plan_build_panics: Counter,
}

/// Phase-labeled `sparselu_plan_build_seconds` family: the wall time of
/// a whole cache-miss resolution (`phase="total"`) plus the structure
/// pipeline's own phase laps mirrored from [`PlanReport`] — the same
/// decomposition `repro plan-bench` writes to `BENCH_plan.json`, live on
/// every scrape.
#[derive(Clone)]
struct PlanBuildPhases {
    total: Histogram,
    ordering: Histogram,
    symbolic: Histogram,
    blocking: Histogram,
    reach: Histogram,
}

impl PlanBuildPhases {
    fn register(registry: &Registry) -> Self {
        let phase = |name: &str| {
            registry.histogram(
                "sparselu_plan_build_seconds",
                "Plan-build wall seconds by structure phase (total = whole cache-miss resolution)",
                &[("phase", name)],
                &obs::BUILD_BUCKETS,
            )
        };
        Self {
            total: phase("total"),
            ordering: phase("ordering"),
            symbolic: phase("symbolic"),
            blocking: phase("blocking"),
            reach: phase("reach"),
        }
    }

    /// One build landed: record the whole-resolution wall time plus the
    /// plan's phase laps (ordering / symbolic / blocking / reach).
    fn observe(&self, wall_seconds: f64, report: &PlanReport) {
        self.total.observe(wall_seconds);
        self.ordering.observe(report.reorder_seconds);
        self.symbolic.observe(report.symbolic_seconds);
        self.blocking.observe(report.preprocess_seconds);
        self.reach.observe(report.plan_extra_seconds);
    }
}

impl RouterMetrics {
    fn register(registry: &Registry) -> Self {
        Self {
            shards_live: registry.gauge(
                "sparselu_router_shards_live",
                "Live shards (tenants with materialized serving state)",
                &[],
            ),
            spin_ups: registry.counter(
                "sparselu_router_spin_ups_total",
                "Shards spun up (first admissions plus revivals)",
                &[],
            ),
            evictions: registry.counter(
                "sparselu_router_evictions_total",
                "Idle shards evicted to make room",
                &[],
            ),
            revivals: registry.counter(
                "sparselu_router_revivals_total",
                "Evicted tenants spun up again",
                &[],
            ),
            plans_warmed: registry.counter(
                "sparselu_plans_warmed_total",
                "Plan files warm-loaded from the plan directory at startup",
                &[],
            ),
            warm_skipped: registry.counter(
                "sparselu_plan_cache_warm_skipped_total",
                "Corrupt or unreadable plan files skipped during cache warming",
                &[],
            ),
            speculative_builds: registry.counter(
                "sparselu_router_speculative_builds_total",
                "Background plan builds started for drifted patterns",
                &[],
            ),
            pattern_drifts: registry.counter(
                "sparselu_router_pattern_drifts_total",
                "Stamps whose coordinates no longer matched their tenant's pattern",
                &[],
            ),
            cache_hits: registry.counter(
                "sparselu_plan_cache_hits_total",
                "Plan-cache lookups served from memory",
                &[],
            ),
            cache_misses: registry.counter(
                "sparselu_plan_cache_misses_total",
                "Plan-cache lookups that had to build (or disk-load) a plan",
                &[],
            ),
            plan_build: PlanBuildPhases::register(registry),
            plan_build_panics: registry.counter(
                "sparselu_plan_build_panics_total",
                "Plan builds that panicked (degraded to per-request errors)",
                &[],
            ),
        }
    }

    /// Mirror the plan cache's own hit/miss counters into the registry
    /// (monotone mirror — see [`Counter::mirror`]).
    fn mirror_cache(&self, cache: &PlanCache) {
        self.cache_hits.mirror(cache.hits() as u64);
        self.cache_misses.mirror(cache.misses() as u64);
    }
}

/// Registry handles for one tenant's series, all labeled
/// `tenant="<016x pattern key>"`. Created at shard spin-up;
/// get-or-create semantics mean a revived shard keeps accumulating into
/// the same series its previous incarnation used.
struct ShardMetrics {
    queue_depth: Gauge,
    submitted: Counter,
    rejected_full: Counter,
    rejected_shed: Counter,
    completed: Counter,
    errored: Counter,
    queue_wait: Histogram,
    exec_time: Histogram,
    batch_size: Histogram,
    tasks_executed: Counter,
    tasks_skipped: Counter,
    refine_iterations: Histogram,
    degraded: Counter,
    deadline_exceeded: Counter,
    pool_timeouts: Counter,
    quarantines: Counter,
    revived: Counter,
}

impl ShardMetrics {
    /// The `tenant` label value: the pattern key as fixed-width hex.
    fn label_of(tenant: TenantId) -> String {
        format!("{:016x}", tenant.0)
    }

    fn register(registry: &Registry, tenant: TenantId) -> Self {
        let value = Self::label_of(tenant);
        let labels: &[(&str, &str)] = &[("tenant", value.as_str())];
        Self {
            queue_depth: registry.gauge(
                "sparselu_tenant_queue_depth",
                "Requests queued on the tenant's shard right now",
                labels,
            ),
            submitted: registry.counter(
                "sparselu_tenant_submitted_total",
                "Requests accepted into the shard queue",
                labels,
            ),
            rejected_full: registry.counter(
                "sparselu_tenant_rejected_total",
                "Requests rejected at admission, by reason",
                &[("tenant", value.as_str()), ("reason", "full")],
            ),
            rejected_shed: registry.counter(
                "sparselu_tenant_rejected_total",
                "Requests rejected at admission, by reason",
                &[("tenant", value.as_str()), ("reason", "shed")],
            ),
            completed: registry.counter(
                "sparselu_tenant_completed_total",
                "Requests executed successfully",
                labels,
            ),
            errored: registry.counter(
                "sparselu_tenant_errored_total",
                "Requests that executed but returned an error",
                labels,
            ),
            queue_wait: registry.histogram(
                "sparselu_tenant_queue_wait_seconds",
                "Time a request sat queued before its batch started executing",
                labels,
                &obs::LATENCY_BUCKETS,
            ),
            exec_time: registry.histogram(
                "sparselu_tenant_exec_seconds",
                "Execution wall time per drained batch",
                labels,
                &obs::LATENCY_BUCKETS,
            ),
            batch_size: registry.histogram(
                "sparselu_tenant_batch_size",
                "Requests coalesced per executed batch",
                labels,
                &obs::BATCH_BUCKETS,
            ),
            tasks_executed: registry.counter(
                "sparselu_tenant_tasks_executed_total",
                "DAG tasks executed on the tenant's behalf",
                labels,
            ),
            tasks_skipped: registry.counter(
                "sparselu_tenant_tasks_skipped_total",
                "DAG tasks skipped by reachability pruning on the tenant's behalf",
                labels,
            ),
            refine_iterations: registry.histogram(
                "sparselu_refine_iterations",
                "Iterative-refinement corrections per mixed-precision solve",
                labels,
                &obs::BATCH_BUCKETS,
            ),
            degraded: registry.counter(
                "sparselu_degraded_total",
                "Requests served degraded (mixed->full fallback or partial->full retry)",
                labels,
            ),
            deadline_exceeded: registry.counter(
                "sparselu_deadline_exceeded_total",
                "Requests that expired in queue past their client deadline",
                labels,
            ),
            pool_timeouts: registry.counter(
                "sparselu_pool_timeouts_total",
                "Requests failed because no session was checked out within the timeout",
                labels,
            ),
            quarantines: registry.counter(
                "sparselu_quarantines_total",
                "Times the tenant was quarantined after a non-finite factor",
                labels,
            ),
            revived: registry.counter(
                "sparselu_quarantine_revivals_total",
                "Quarantines lifted by a successful background pool rebuild",
                labels,
            ),
        }
    }

    /// Record one drain's outcomes. Per-request series (queue wait,
    /// completion counters) get one observation per outcome; per-batch
    /// series (batch size, exec time) get one observation per executed
    /// batch — detected by walking the outcome list in batch-sized
    /// strides, since a batch's reports are adjacent by construction of
    /// [`Batcher::drain`].
    fn absorb(&self, outcomes: &[Result<ServeReport, ServeError>]) {
        let mut i = 0;
        while i < outcomes.len() {
            match &outcomes[i] {
                Ok(leader) => {
                    self.batch_size.observe(leader.batch_size as f64);
                    self.exec_time.observe(leader.exec_seconds);
                    let run = leader.batch_size.clamp(1, outcomes.len() - i);
                    for outcome in &outcomes[i..i + run] {
                        match outcome {
                            Ok(rep) => {
                                self.completed.inc();
                                self.queue_wait.observe(rep.queue_seconds);
                                self.tasks_executed.add(rep.tasks_executed as u64);
                                self.tasks_skipped.add(rep.tasks_skipped as u64);
                                if let Some(iters) = rep.refine_iterations {
                                    self.refine_iterations.observe(iters as f64);
                                }
                                if rep.degraded {
                                    self.degraded.inc();
                                }
                            }
                            Err(e) => self.errored_by(e),
                        }
                    }
                    i += run;
                }
                Err(e) => {
                    self.errored_by(e);
                    i += 1;
                }
            }
        }
    }

    /// Count one errored request, splitting the lifetime-enforcement
    /// kinds (queue deadline, pool timeout) into their own series.
    fn errored_by(&self, e: &ServeError) {
        self.errored.inc();
        match e {
            ServeError::DeadlineExceeded { .. } => self.deadline_exceeded.inc(),
            ServeError::PoolTimeout { .. } => self.pool_timeouts.inc(),
            _ => {}
        }
    }
}

/// The plan-dependent half of a shard, materialized once the plan is
/// resolved. Shards admitted through [`Router::admit`] are born with it;
/// speculative shards ([`Router::admit_background`]) gain it when their
/// background build lands.
struct Serving {
    plan: Arc<FactorPlan>,
    /// The tenant's session pool, swappable so a quarantine rebuild
    /// can replace poisoned sessions wholesale: readers (drain, health,
    /// autoscale) take the read side; only the background rebuild
    /// thread ever takes the write side, and only for the swap itself.
    pool: Arc<RwLock<SessionPool>>,
}

/// Quarantine state of one shard, shared with the background rebuild
/// thread (which lifts `active` once the fresh pool is in place).
struct Quarantine {
    /// Fail-fast flag: while set, submits and drains short-circuit
    /// with [`ServeError::TenantQuarantined`].
    active: AtomicBool,
    /// Cumulative quarantine trips.
    total: AtomicUsize,
    /// Quarantines lifted by a successful pool swap.
    revivals: AtomicUsize,
}

/// Completion slot of one speculative background build: the builder
/// thread publishes `Ok(())` (serving state installed) or the build
/// error, and wakes anything blocked on the shard.
struct PendingBuild {
    result: Mutex<Option<Result<(), ServeError>>>,
    ready: Condvar,
}

/// One tenant's serving state: the immutable plan plus this pattern's
/// mutable serving machinery. Everything mutable is behind its own lock,
/// so shards never contend with each other.
struct Shard {
    tenant: TenantId,
    /// Resolved plan + session pool. Empty only while a speculative
    /// background build is still pending.
    serving: OnceLock<Serving>,
    /// Present only on speculatively admitted shards; resolved exactly
    /// once by the background builder thread.
    pending: Option<Arc<PendingBuild>>,
    /// The background builder thread, held so it can be reaped once its
    /// result is published instead of being left permanently detached.
    build_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    batcher: Mutex<Batcher>,
    stats: Mutex<TenantStats>,
    metrics: ShardMetrics,
    /// Set (under the batcher lock, with the queue verified empty) when
    /// the shard is evicted. A submit that looked the shard up *before*
    /// the eviction but enqueues *after* would otherwise land its
    /// request on an orphaned queue nobody will ever drain; checking
    /// this flag under the same lock closes that window.
    retired: AtomicBool,
    /// Consecutive out-of-pattern stamps seen by
    /// [`Router::submit_stamp_coords`]; an in-pattern stamp resets it.
    drift_strikes: AtomicUsize,
    /// Set when a drain surfaces [`FactorError::NonFinite`]: the
    /// tenant's numeric state cannot be trusted, so the shard fails
    /// fast while a background thread rebuilds its session pool.
    quarantine: Arc<Quarantine>,
    /// The in-flight (or last finished) quarantine rebuild thread,
    /// held so it can be reaped instead of left permanently detached.
    revive_thread: Mutex<Option<std::thread::JoinHandle<()>>>,
    /// [`RouterConfig::checkout_timeout`], captured at spin-up.
    checkout_timeout: Option<Duration>,
    /// The router's registry, kept so the rebuild thread can re-attach
    /// pool metrics (get-or-create: the fresh pool keeps accumulating
    /// into the same tenant-labeled series).
    registry: Arc<Registry>,
}

impl Shard {
    /// The shard's serving state, blocking on a pending background build
    /// if one is in flight. A failed build comes back as its
    /// [`ServeError`] — the shard stays alive and every queued request
    /// gets the error individually ([`Batcher::fail_all`]).
    fn ensure_serving(&self) -> Result<&Serving, ServeError> {
        if let Some(s) = self.serving.get() {
            self.reap_builder();
            return Ok(s);
        }
        let pending =
            self.pending.as_ref().expect("a shard without serving state has a pending build");
        let mut result = pending.result.lock().unwrap();
        while result.is_none() {
            result = pending.ready.wait(result).unwrap();
        }
        let outcome = match result.as_ref().expect("pending build published") {
            Ok(()) => Ok(()),
            Err(e) => Err(e.clone()),
        };
        drop(result);
        // the builder publishes its result as its last act, so it is
        // exiting (or gone) — join it rather than leaving it detached
        self.reap_builder();
        outcome.map(|()| self.serving.get().expect("builder installed serving state"))
    }

    /// Join the background builder thread if one ran and finished. Free
    /// on ordinary shards (`pending` is `None`); on speculative shards
    /// this is only called after the build's result is published, so the
    /// join never blocks on plan construction.
    fn reap_builder(&self) {
        if self.pending.is_some() {
            if let Some(handle) = self.build_thread.lock().unwrap().take() {
                let _ = handle.join();
            }
        }
    }

    /// Execute everything queued on this shard. The batcher lock is held
    /// for the duration, serializing drains *within* the tenant — which
    /// is exactly the per-tenant total order timestep streams need —
    /// while other shards drain in parallel on their own locks.
    fn drain(&self) -> Vec<Result<ServeReport, ServeError>> {
        self.reap_reviver();
        let mut batcher = self.batcher.lock().unwrap();
        if batcher.is_empty() {
            return Vec::new();
        }
        let outcomes = if self.quarantine.active.load(Ordering::Acquire) {
            // poisoned factors: fail fast while the background rebuild
            // swaps a fresh pool in
            batcher.fail_all(&ServeError::TenantQuarantined { tenant: self.tenant.0 })
        } else {
            match self.ensure_serving() {
                Ok(serving) => {
                    // LIFO checkout hands back the warm session holding
                    // this tenant's current factors; serialized drains
                    // mean the pool only blocks here under injected
                    // stalls or leaked checkouts
                    let pool = serving.pool.read().unwrap();
                    let session = match self.checkout_timeout {
                        Some(limit) => pool.checkout_timeout(limit),
                        None => Some(pool.checkout()),
                    };
                    match session {
                        Some(mut session) => batcher.drain(&mut session),
                        None => {
                            let waited = self.checkout_timeout.expect("timeout was configured");
                            batcher.fail_all(&ServeError::PoolTimeout { waited })
                        }
                    }
                }
                // the plan build failed (e.g. a structurally singular
                // pattern): every queued request gets the error, the
                // shard and the process survive
                Err(e) => batcher.fail_all(&e),
            }
        };
        // a non-finite factor means the tenant's numeric state cannot
        // be trusted: quarantine (exactly once per trip — the swap
        // guards against a racing drain) and rebuild off the serving
        // path
        let poisoned = outcomes
            .iter()
            .any(|o| matches!(o, Err(ServeError::Factor(FactorError::NonFinite { .. }))));
        if poisoned && !self.quarantine.active.swap(true, Ordering::AcqRel) {
            self.quarantine.total.fetch_add(1, Ordering::Relaxed);
            self.metrics.quarantines.inc();
            self.begin_rebuild();
        }
        // the queue was fully consumed; submits racing this drain are
        // still blocked on the batcher lock, so 0 is exact here
        self.metrics.queue_depth.set(0.0);
        drop(batcher);
        self.stats.lock().unwrap().absorb(&outcomes);
        self.metrics.absorb(&outcomes);
        outcomes
    }

    /// Kick off the quarantine rebuild: a background thread builds a
    /// fresh [`SessionPool`] against the (immutable, still-good) plan,
    /// swaps it in place of the poisoned one, and lifts the
    /// quarantine. Until then, submits and drains fail fast with
    /// [`ServeError::TenantQuarantined`]; afterwards the tenant's next
    /// refactorize restores clean factors.
    fn begin_rebuild(&self) {
        let Some(serving) = self.serving.get() else {
            // only a serving shard can surface NonFinite; never leave
            // the flag stuck if that invariant somehow breaks
            self.quarantine.active.store(false, Ordering::Release);
            return;
        };
        let plan = serving.plan.clone();
        let slot = serving.pool.clone();
        let sessions = slot.read().unwrap().max_sessions();
        let quarantine = self.quarantine.clone();
        let registry = self.registry.clone();
        let revived = self.metrics.revived.clone();
        let tenant = self.tenant;
        let spawned = std::thread::Builder::new().name("lu-shard-rebuild".into()).spawn(move || {
            let fresh = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                let label = ShardMetrics::label_of(tenant);
                let pool_metrics =
                    PoolMetrics::register(&registry, &[("tenant", label.as_str())]);
                SessionPool::with_metrics(plan, sessions, pool_metrics)
            }));
            match fresh {
                Ok(pool) => {
                    *slot.write().unwrap() = pool;
                    quarantine.revivals.fetch_add(1, Ordering::Relaxed);
                    revived.inc();
                }
                // pool construction cannot realistically panic, but a
                // tenant stuck quarantined forever is worse than
                // serving on sessions that refactorize themselves
                // clean
                Err(_) => {
                    eprintln!("router: shard rebuild panicked; lifting quarantine anyway")
                }
            }
            quarantine.active.store(false, Ordering::Release);
        });
        match spawned {
            Ok(handle) => {
                // reap a previous trip's (finished) thread, hold this one
                if let Some(old) = self.revive_thread.lock().unwrap().replace(handle) {
                    let _ = old.join();
                }
            }
            Err(e) => {
                // spawn failed (resource exhaustion): lift the
                // quarantine rather than stranding the tenant
                eprintln!("router: cannot spawn shard-rebuild thread: {e}");
                self.quarantine.active.store(false, Ordering::Release);
            }
        }
    }

    /// Join a finished rebuild thread (free when none ran). Only joins
    /// once the quarantine is lifted, so it never blocks on a rebuild
    /// still in flight.
    fn reap_reviver(&self) {
        if !self.quarantine.active.load(Ordering::Acquire) {
            if let Some(handle) = self.revive_thread.lock().unwrap().take() {
                let _ = handle.join();
            }
        }
    }
}

struct RouterState {
    /// Live shards, least-recently-touched first (admission/submission
    /// order — kept in lockstep with the plan cache via
    /// [`PlanCache::touch`]).
    shards: Vec<Arc<Shard>>,
    /// Tenants that once had a shard and were evicted (for the revival
    /// counter).
    evicted: HashSet<u64>,
    spin_ups: usize,
    evictions: usize,
    revivals: usize,
    plans_warmed: usize,
    plans_warm_skipped: usize,
    speculative_builds: usize,
}

/// Multi-tenant serving front-end over pattern-keyed shards. See the
/// [module docs](self) for the full story.
pub struct Router {
    cfg: RouterConfig,
    opts: SolveOptions,
    state: Mutex<RouterState>,
    /// Shared build-deduplicating plan cache. Outside the state lock so
    /// a plan build (potentially hundreds of milliseconds) never blocks
    /// routing, draining, or admissions of other patterns. Lock order
    /// where both are held: `state` before the cache's lock.
    cache: Arc<SharedPlanCache>,
    registry: Arc<Registry>,
    rm: RouterMetrics,
    /// Pins the process-wide executor for this worker count so the
    /// executor series registered in [`Router::new`] stay live (and the
    /// pool's threads warm) for the router's whole lifetime. Plan builds
    /// run their parallel passes on it too.
    executor: Arc<Executor>,
}

impl Router {
    /// Router serving every tenant under one set of solve options. If
    /// `cfg.plan_dir` is set, the plan cache is warmed from it now
    /// (best-effort: unreadable files are skipped, a missing directory
    /// is created).
    pub fn new(opts: SolveOptions, cfg: RouterConfig) -> Self {
        assert!(cfg.max_shards > 0, "Router needs max_shards >= 1");
        assert!(cfg.plan_cache_capacity >= cfg.max_shards, "cache must cover the live shards");
        assert!(cfg.drift_storm_threshold > 0, "drift_storm_threshold must be >= 1");
        let cache = Arc::new(SharedPlanCache::new(cfg.plan_cache_capacity));
        let mut plans_warmed = 0;
        let mut plans_warm_skipped = 0;
        if let Some(dir) = &cfg.plan_dir {
            if let Err(e) = std::fs::create_dir_all(dir) {
                eprintln!("router: cannot create plan dir {}: {e}", dir.display());
            } else {
                match cache.lock().warm_from_dir(dir) {
                    Ok(warm) => {
                        plans_warmed = warm.loaded;
                        plans_warm_skipped = warm.skipped.len();
                        for (path, err) in &warm.skipped {
                            eprintln!("router: skipped plan file {}: {err}", path.display());
                        }
                    }
                    Err(e) => eprintln!("router: warming from {} failed: {e}", dir.display()),
                }
            }
        }
        let registry = cfg.registry.clone().unwrap_or_else(Registry::global);
        let rm = RouterMetrics::register(&registry);
        rm.plans_warmed.add(plans_warmed as u64);
        rm.warm_skipped.add(plans_warm_skipped as u64);
        rm.mirror_cache(&cache.lock());
        // mirror the shared executor's scheduler-health counters into
        // the registry on every scrape
        let executor = Executor::shared(opts.workers);
        obs::register_executor(&registry, &executor);
        Self {
            cfg,
            opts,
            state: Mutex::new(RouterState {
                shards: Vec::new(),
                evicted: HashSet::new(),
                spin_ups: 0,
                evictions: 0,
                revivals: 0,
                plans_warmed,
                plans_warm_skipped,
                speculative_builds: 0,
            }),
            cache,
            registry,
            rm,
            executor,
        }
    }

    /// Solve options every tenant is served under.
    pub fn options(&self) -> &SolveOptions {
        &self.opts
    }

    /// The registry this router publishes metrics to.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The shared executor serving this router's DAG runs.
    pub fn executor(&self) -> &Arc<Executor> {
        &self.executor
    }

    /// The tenant id `a`'s pattern routes to (no shard is created).
    pub fn tenant_of(&self, a: &Csc) -> TenantId {
        TenantId(PlanCache::key_for(a, &self.opts))
    }

    /// Admit a matrix's sparsity pattern: return its tenant id, spinning
    /// a shard up if none is live. The plan is resolved through the
    /// shared cache (hit, disk-warmed file, or cold build — in that
    /// order of cost); freshly built plans are persisted to `plan_dir`
    /// when configured.
    ///
    /// Fails with [`ServeError::RouterFull`] when the shard table is at
    /// capacity and every live shard has queued or in-flight work.
    pub fn admit(&self, a: &Csc) -> Result<TenantId, ServeError> {
        let tenant = self.tenant_of(a);
        if self.touch_live(tenant) {
            return Ok(tenant);
        }
        // resolve the plan OUTSIDE the state lock: a cold build (the
        // dominant admission cost) no longer stalls routing, draining or
        // admissions of other patterns, and racers on the same unseen
        // pattern share one build through the SharedPlanCache
        let build_start = Instant::now();
        let (plan, built) = self
            .cache
            .get_or_build_traced(a, &self.opts, Some(&self.executor))
            .map_err(ServeError::Factor)?;
        if built {
            self.rm.plan_build.observe(build_start.elapsed().as_secs_f64(), &plan.report);
            if let Some(dir) = &self.cfg.plan_dir {
                if let Err(e) = persist::save_plan_to_dir(&plan, dir) {
                    eprintln!("router: persisting plan to {} failed: {e}", dir.display());
                }
            }
        }
        self.rm.mirror_cache(&self.cache.lock());
        let shard = self.new_shard(tenant, Some(plan), None);
        self.install_shard(tenant, shard)?;
        Ok(tenant)
    }

    /// If a shard for `tenant` is live, refresh its recency (shard table
    /// + plan cache) and report `true`.
    fn touch_live(&self, tenant: TenantId) -> bool {
        let mut st = self.state.lock().unwrap();
        if let Some(pos) = st.shards.iter().position(|s| s.tenant == tenant) {
            let shard = st.shards.remove(pos);
            st.shards.push(shard);
            self.cache.lock().touch(tenant.0);
            true
        } else {
            false
        }
    }

    /// Assemble a shard. `plan` present ⇒ born serving; otherwise
    /// `pending` must carry the background build that will finish it.
    fn new_shard(
        &self,
        tenant: TenantId,
        plan: Option<Arc<FactorPlan>>,
        pending: Option<Arc<PendingBuild>>,
    ) -> Arc<Shard> {
        let batcher = Batcher::new(self.cfg.shard_queue)
            .with_partial_threshold(self.cfg.partial_threshold)
            .with_stamp_coalescing(self.cfg.coalesce_stamps)
            .with_precision(self.cfg.precision);
        let serving = OnceLock::new();
        if let Some(plan) = plan {
            let tenant_label = ShardMetrics::label_of(tenant);
            let pool_metrics =
                PoolMetrics::register(&self.registry, &[("tenant", tenant_label.as_str())]);
            let pool =
                SessionPool::with_metrics(plan.clone(), self.cfg.sessions_per_shard, pool_metrics);
            let _ = serving.set(Serving { plan, pool: Arc::new(RwLock::new(pool)) });
        }
        Arc::new(Shard {
            tenant,
            serving,
            pending,
            build_thread: Mutex::new(None),
            batcher: Mutex::new(batcher),
            stats: Mutex::new(TenantStats::default()),
            metrics: ShardMetrics::register(&self.registry, tenant),
            retired: AtomicBool::new(false),
            drift_strikes: AtomicUsize::new(0),
            quarantine: Arc::new(Quarantine {
                active: AtomicBool::new(false),
                total: AtomicUsize::new(0),
                revivals: AtomicUsize::new(0),
            }),
            revive_thread: Mutex::new(None),
            checkout_timeout: self.cfg.checkout_timeout,
            registry: self.registry.clone(),
        })
    }

    /// Install `shard` into the live table, evicting to make room if
    /// needed. Returns `false` when a concurrent admission of the same
    /// tenant won the race (its shard is live and freshly touched — the
    /// plan `Arc` is shared either way, so nothing is lost).
    fn install_shard(&self, tenant: TenantId, shard: Arc<Shard>) -> Result<bool, ServeError> {
        let mut st = self.state.lock().unwrap();
        if let Some(pos) = st.shards.iter().position(|s| s.tenant == tenant) {
            let existing = st.shards.remove(pos);
            st.shards.push(existing);
            self.cache.lock().touch(tenant.0);
            return Ok(false);
        }
        if st.shards.len() == self.cfg.max_shards {
            self.evict_locked(&mut st)?;
        }
        st.shards.push(shard);
        st.spin_ups += 1;
        self.rm.spin_ups.inc();
        self.rm.shards_live.set(st.shards.len() as f64);
        if st.evicted.remove(&tenant.0) {
            st.revivals += 1;
            self.rm.revivals.inc();
        }
        Ok(true)
    }

    /// Admit a pattern **speculatively**: the shard (and its tenant id)
    /// is live immediately and accepts submissions, while the plan
    /// builds on a detached background thread — no caller ever blocks on
    /// the build. The first drain (or [`Router::plan_of`] /
    /// [`Shard`]-level access) after the build lands serves normally; if
    /// the build fails, every queued request gets the error back
    /// per-request and the shard survives.
    ///
    /// This is the router's answer to an out-of-pattern stamp storm
    /// ([`Router::submit_stamp_coords`]): the drifted pattern is
    /// re-admitted as its own tenant with no client-visible stall.
    pub fn admit_background(&self, a: &Csc) -> Result<TenantId, ServeError> {
        let tenant = self.tenant_of(a);
        if self.touch_live(tenant) {
            return Ok(tenant);
        }
        let pending = Arc::new(PendingBuild { result: Mutex::new(None), ready: Condvar::new() });
        let shard = self.new_shard(tenant, None, Some(pending.clone()));
        if !self.install_shard(tenant, shard.clone())? {
            return Ok(tenant); // raced: an equivalent shard is already live
        }
        self.state.lock().unwrap().speculative_builds += 1;
        self.rm.speculative_builds.inc();
        let cache = self.cache.clone();
        let executor = self.executor.clone();
        let opts = self.opts.clone();
        let registry = self.registry.clone();
        let plan_dir = self.cfg.plan_dir.clone();
        let sessions_per_shard = self.cfg.sessions_per_shard;
        let plan_build = self.rm.plan_build.clone();
        let build_panics = self.rm.plan_build_panics.clone();
        let matrix = a.clone();
        let builder_shard = shard.clone();
        let pending_thread = pending.clone();
        let spawned = std::thread::Builder::new().name("lu-plan-build".into()).spawn(move || {
            let start = Instant::now();
            // the whole build-and-install sequence is unwind-guarded: a
            // panic anywhere in it must still resolve `pending` — queued
            // requests then fail per-request instead of hanging forever
            // on a slot nobody will ever publish
            let published = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                match cache.get_or_build_traced(&matrix, &opts, Some(&executor)) {
                    Ok((plan, built)) => {
                        if built {
                            plan_build.observe(start.elapsed().as_secs_f64(), &plan.report);
                            if let Some(dir) = &plan_dir {
                                if let Err(e) = persist::save_plan_to_dir(&plan, dir) {
                                    eprintln!(
                                        "router: persisting plan to {} failed: {e}",
                                        dir.display()
                                    );
                                }
                            }
                        }
                        let label = ShardMetrics::label_of(tenant);
                        let pool_metrics =
                            PoolMetrics::register(&registry, &[("tenant", label.as_str())]);
                        let pool = SessionPool::with_metrics(
                            plan.clone(),
                            sessions_per_shard,
                            pool_metrics,
                        );
                        let _ = builder_shard
                            .serving
                            .set(Serving { plan, pool: Arc::new(RwLock::new(pool)) });
                        Ok(())
                    }
                    Err(e) => Err(ServeError::Factor(e)),
                }
            }))
            .unwrap_or_else(|_| Err(ServeError::Factor(FactorError::TaskPanic)));
            // the plan cache converts a panic *inside the build itself*
            // into TaskPanic before it reaches us; either origin is a
            // plan-build panic
            if matches!(published, Err(ServeError::Factor(FactorError::TaskPanic))) {
                build_panics.inc();
            }
            *pending_thread.result.lock().unwrap() = Some(published);
            pending_thread.ready.notify_all();
        });
        match spawned {
            // hold the handle so the builder is reaped once it resolves
            // (Shard::reap_builder), never left permanently detached
            Ok(handle) => *shard.build_thread.lock().unwrap() = Some(handle),
            Err(e) => {
                // thread spawn failed (resource exhaustion): resolve the
                // pending slot so queued requests error instead of hanging
                eprintln!("router: cannot spawn plan-build thread: {e}");
                *pending.result.lock().unwrap() =
                    Some(Err(ServeError::Factor(FactorError::TaskPanic)));
                pending.ready.notify_all();
            }
        }
        Ok(tenant)
    }

    /// Submit a device stamp by **coordinates** against the matrix the
    /// client currently holds, with pattern-drift detection. When
    /// `current` still matches `tenant`'s pattern, this is an ordinary
    /// [`Request::Stamp`] submission (and the drift strike count
    /// resets). When it does not, the strike count grows: below
    /// [`RouterConfig::drift_storm_threshold`] each drifted stamp is
    /// rejected with [`ServeError::PatternDrift`]; at the threshold the
    /// storm is real, the drifted pattern is spun up in the background
    /// ([`Router::admit_background`]) and this request is transparently
    /// re-routed to the new tenant as a full refactorize — the returned
    /// tenant id tells the client where its traffic now lives.
    pub fn submit_stamp_coords(
        &self,
        tenant: TenantId,
        current: &Csc,
        coords: &[(usize, usize, f64)],
    ) -> Result<TenantId, ServeError> {
        let actual = self.tenant_of(current);
        if actual == tenant {
            let shard = self.shard_of(tenant)?;
            shard.drift_strikes.store(0, Ordering::Relaxed);
            let changes = ChangeSet::from_coords(current, coords).map_err(ServeError::Factor)?;
            self.submit(tenant, Request::Stamp { changes })?;
            return Ok(tenant);
        }
        // the stamp's matrix no longer routes to `tenant`: count the
        // strike against the shard the client *thinks* it is talking to
        self.rm.pattern_drifts.inc();
        let shard = self.shard_of(tenant)?;
        let strikes = shard.drift_strikes.fetch_add(1, Ordering::Relaxed) + 1;
        if strikes < self.cfg.drift_storm_threshold {
            return Err(ServeError::PatternDrift { tenant: tenant.0, drifted: actual.0, strikes });
        }
        shard.drift_strikes.store(0, Ordering::Relaxed);
        // storm confirmed: spin the drifted pattern up without blocking
        // on its plan build, and ride this stamp in as the new tenant's
        // seeding refactorize
        let drifted = self.admit_background(current)?;
        let mut values = current.values.clone();
        for &(r, c, v) in coords {
            match current.value_index(r, c) {
                Some(k) => values[k] = v,
                None => {
                    return Err(ServeError::Factor(FactorError::OutOfPattern { row: r, col: c }))
                }
            }
        }
        self.submit(drifted, Request::Refactorize { values })?;
        Ok(drifted)
    }

    /// Evict the least-recently-used **idle** shard (empty queue, no
    /// session checked out), ranking idleness by the plan cache's own
    /// LRU order: a shard whose plan the cache already evicted ranks
    /// before everything still cached. Busy shards are never evicted.
    fn evict_locked(&self, st: &mut RouterState) -> Result<(), ServeError> {
        let order = self.cache.lock().keys_lru();
        let rank = |key: u64| -> i64 {
            order.iter().position(|&k| k == key).map_or(-1, |p| p as i64)
        };
        // a shard still waiting on its speculative background build has
        // no pool yet and is never evictable (its queue will be served
        // the moment the build lands)
        let pool_idle = |shard: &Shard| match shard.serving.get() {
            Some(s) => s.pool.read().unwrap().stats().in_use == 0,
            None => false,
        };
        // pass 1: rank the currently idle shards (try_lock: a held
        // batcher lock means a drain is in flight — that shard is busy)
        let mut candidates: Vec<(usize, i64)> = st
            .shards
            .iter()
            .enumerate()
            .filter_map(|(i, shard)| {
                let queue_empty = match shard.batcher.try_lock() {
                    Ok(b) => b.is_empty(),
                    Err(_) => false,
                };
                if queue_empty && pool_idle(shard) {
                    Some((i, rank(shard.tenant.0)))
                } else {
                    None
                }
            })
            .collect();
        candidates.sort_by_key(|&(_, r)| r);
        // pass 2: retire the best candidate that is *still* idle under
        // its batcher lock. Setting `retired` with the queue verified
        // empty under that lock means a racing submit (which looked the
        // shard up before we removed it) either already enqueued — and
        // we see the queue non-empty and skip — or will see the flag
        // and get UnknownTenant. No accepted request is ever orphaned.
        for (pos, _) in candidates {
            let shard = &st.shards[pos];
            let guard = shard.batcher.lock().unwrap();
            if !guard.is_empty() || !pool_idle(shard) {
                continue;
            }
            shard.retired.store(true, Ordering::Release);
            drop(guard);
            let shard = st.shards.remove(pos);
            st.evicted.insert(shard.tenant.0);
            st.evictions += 1;
            self.rm.evictions.inc();
            self.rm.shards_live.set(st.shards.len() as f64);
            shard.metrics.queue_depth.set(0.0);
            // the plan itself stays in the cache under its own LRU life
            // — revival is a cache hit until the cache too moves on
            return Ok(());
        }
        Err(ServeError::RouterFull { max_shards: self.cfg.max_shards })
    }

    /// Clone the live shard for `tenant`, refreshing its recency (both
    /// in the shard table and the plan cache).
    fn shard_of(&self, tenant: TenantId) -> Result<Arc<Shard>, ServeError> {
        let mut st = self.state.lock().unwrap();
        let Some(pos) = st.shards.iter().position(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        let shard = st.shards.remove(pos);
        st.shards.push(shard.clone());
        self.cache.lock().touch(tenant.0);
        Ok(shard)
    }

    /// Enqueue a request on its tenant's shard at [`Priority::High`]. A
    /// full shard queue comes back as [`ServeError::ShardFull`] —
    /// backpressure scoped to this tenant alone.
    pub fn submit(&self, tenant: TenantId, request: Request) -> Result<(), ServeError> {
        self.submit_with_priority(tenant, request, Priority::High)
    }

    /// Enqueue a request under an explicit priority class.
    /// [`Priority::Low`] traffic is admitted only below the shard's
    /// shedding watermark (set by the autoscaler under saturation), so
    /// best-effort load is turned away — as [`ServeError::ShardFull`],
    /// same as a genuinely full queue — before it can crowd out
    /// SLO-bound clients. Priority never reorders admitted requests.
    pub fn submit_with_priority(
        &self,
        tenant: TenantId,
        request: Request,
        priority: Priority,
    ) -> Result<(), ServeError> {
        let shard = self.shard_of(tenant)?;
        let mut batcher = shard.batcher.lock().unwrap();
        // the shard may have been evicted between the lookup above and
        // taking its lock; the flag is only ever set under this lock, so
        // checking it here guarantees an accepted request lands on a
        // queue that will still be drained
        if shard.retired.load(Ordering::Acquire) {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        }
        // a quarantined tenant fails fast rather than queueing work
        // that the next drain would only fail anyway
        if shard.quarantine.active.load(Ordering::Acquire) {
            return Err(ServeError::TenantQuarantined { tenant: tenant.0 });
        }
        let result = batcher.submit_with_priority(request, priority);
        // a low-priority rejection with the queue not actually full is a
        // shed, not a capacity rejection — label it as such
        let was_shed = result.is_err() && batcher.len() < batcher.capacity();
        shard.metrics.queue_depth.set(batcher.len() as f64);
        drop(batcher);
        let mut stats = shard.stats.lock().unwrap();
        match result {
            Ok(()) => {
                stats.submitted += 1;
                shard.metrics.submitted.inc();
                Ok(())
            }
            Err(ServeError::QueueFull { capacity }) => {
                stats.rejected += 1;
                if was_shed {
                    shard.metrics.rejected_shed.inc();
                } else {
                    shard.metrics.rejected_full.inc();
                }
                Err(ServeError::ShardFull { tenant: tenant.0, capacity })
            }
            // Batcher::submit only rejects on a full queue today; pass
            // anything future through untouched (it is not an admission
            // rejection, so it does not count as one)
            Err(other) => Err(other),
        }
    }

    /// Drain one tenant's queue, returning its outcomes in submission
    /// order.
    pub fn drain_tenant(
        &self,
        tenant: TenantId,
    ) -> Result<Vec<Result<ServeReport, ServeError>>, ServeError> {
        Ok(self.shard_of(tenant)?.drain())
    }

    /// Drain every live shard on a pool of `workers` threads. Each shard
    /// is drained by exactly one worker (per-tenant order preserved);
    /// distinct tenants execute concurrently. Returns the non-empty
    /// outcome groups, one per tenant that had queued work.
    pub fn drain_all(
        &self,
        workers: usize,
    ) -> Vec<(TenantId, Vec<Result<ServeReport, ServeError>>)> {
        let shards: Vec<Arc<Shard>> = self.state.lock().unwrap().shards.clone();
        if shards.is_empty() {
            return Vec::new();
        }
        let workers = workers.clamp(1, shards.len());
        let next = AtomicUsize::new(0);
        let mut grouped: Vec<(TenantId, Vec<Result<ServeReport, ServeError>>)> =
            shards.iter().map(|s| (s.tenant, Vec::new())).collect();
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..workers)
                .map(|_| {
                    let (next, shards) = (&next, &shards);
                    scope.spawn(move || {
                        let mut drained = Vec::new();
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= shards.len() {
                                break;
                            }
                            let outcomes = shards[i].drain();
                            if !outcomes.is_empty() {
                                drained.push((i, outcomes));
                            }
                        }
                        drained
                    })
                })
                .collect();
            for handle in handles {
                for (i, outcomes) in handle.join().expect("drain worker panicked") {
                    grouped[i].1 = outcomes;
                }
            }
        });
        grouped.retain(|(_, outcomes)| !outcomes.is_empty());
        grouped
    }

    /// Queued (undrained) requests on a tenant's shard.
    pub fn queued(&self, tenant: TenantId) -> Result<usize, ServeError> {
        let st = self.state.lock().unwrap();
        let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        Ok(shard.batcher.lock().unwrap().len())
    }

    /// The plan a tenant's shard serves against. Blocks until a pending
    /// speculative build resolves; a failed build comes back as its
    /// error.
    pub fn plan_of(&self, tenant: TenantId) -> Result<Arc<FactorPlan>, ServeError> {
        let shard = {
            let st = self.state.lock().unwrap();
            let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
                return Err(ServeError::UnknownTenant { tenant: tenant.0 });
            };
            shard.clone()
        };
        Ok(shard.ensure_serving()?.plan.clone())
    }

    /// Cumulative metrics of one tenant (read-only: does not touch LRU
    /// recency).
    pub fn tenant_stats(&self, tenant: TenantId) -> Result<TenantStats, ServeError> {
        let st = self.state.lock().unwrap();
        let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
            return Err(ServeError::UnknownTenant { tenant: tenant.0 });
        };
        let stats = *shard.stats.lock().unwrap();
        Ok(stats)
    }

    /// Live tenants, least-recently-touched first.
    pub fn tenants(&self) -> Vec<TenantId> {
        self.state.lock().unwrap().shards.iter().map(|s| s.tenant).collect()
    }

    /// Point-in-time health of every live shard, for the autoscaler (or
    /// any other control plane). Read-only: does not touch LRU recency.
    pub fn health(&self) -> Vec<TenantHealth> {
        let shards: Vec<Arc<Shard>> = self.state.lock().unwrap().shards.clone();
        shards
            .iter()
            .map(|shard| {
                let (queue_depth, queue_capacity, low_priority_limit) = {
                    let b = shard.batcher.lock().unwrap();
                    (b.len(), b.capacity(), b.low_priority_limit())
                };
                // a shard still waiting on its background build has no
                // pool yet — report zero sessions rather than blocking
                // the control loop on the build
                let (sessions_target, sessions_created, sessions_in_use) =
                    match shard.serving.get() {
                        Some(s) => {
                            let pool = s.pool.read().unwrap();
                            let stats = pool.stats();
                            (pool.max_sessions(), stats.created, stats.in_use)
                        }
                        None => (0, 0, 0),
                    };
                TenantHealth {
                    tenant: shard.tenant,
                    queue_depth,
                    queue_capacity,
                    low_priority_limit,
                    sessions_target,
                    sessions_created,
                    sessions_in_use,
                    queue_wait: shard.metrics.queue_wait.snapshot(),
                    quarantined: shard.quarantine.active.load(Ordering::Acquire),
                    quarantines: shard.quarantine.total.load(Ordering::Relaxed),
                    quarantine_revivals: shard.quarantine.revivals.load(Ordering::Relaxed),
                }
            })
            .collect()
    }

    /// Retarget one shard's serving capacity: session-pool cap, queue
    /// bound and low-priority shedding watermark (see
    /// [`Batcher::set_low_priority_limit`];
    /// `low_priority_limit == queue_capacity` turns shedding off). The
    /// autoscaler's only write path into the router. Queued and
    /// in-flight requests are never dropped by a resize.
    pub fn scale_tenant(
        &self,
        tenant: TenantId,
        sessions: usize,
        queue_capacity: usize,
        low_priority_limit: usize,
    ) -> Result<(), ServeError> {
        let shard = {
            let st = self.state.lock().unwrap();
            let Some(shard) = st.shards.iter().find(|s| s.tenant == tenant) else {
                return Err(ServeError::UnknownTenant { tenant: tenant.0 });
            };
            shard.clone()
        };
        // queue knobs always apply; the pool resize waits until the
        // shard is actually serving (a pending build has no pool yet)
        if let Some(s) = shard.serving.get() {
            s.pool.read().unwrap().resize(sessions);
        }
        let mut batcher = shard.batcher.lock().unwrap();
        batcher.set_capacity(queue_capacity);
        batcher.set_low_priority_limit(low_priority_limit);
        Ok(())
    }

    /// Router-level counters.
    pub fn stats(&self) -> RouterStats {
        let st = self.state.lock().unwrap();
        let (cache_hits, cache_misses) = {
            let cache = self.cache.lock();
            (cache.hits(), cache.misses())
        };
        RouterStats {
            shards_live: st.shards.len(),
            spin_ups: st.spin_ups,
            evictions: st.evictions,
            revivals: st.revivals,
            plans_warmed: st.plans_warmed,
            plans_warm_skipped: st.plans_warm_skipped,
            speculative_builds: st.speculative_builds,
            cache_hits,
            cache_misses,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen;

    fn small_router(max_shards: usize, shard_queue: usize) -> Router {
        Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards,
                plan_cache_capacity: max_shards.max(2) * 2,
                shard_queue,
                ..RouterConfig::default()
            },
        )
    }

    #[test]
    fn admit_routes_same_pattern_to_same_tenant() {
        let router = small_router(4, 8);
        let a = gen::grid2d_laplacian(6, 6);
        let t1 = router.admit(&a).unwrap();
        // same pattern, different values: same tenant, no new shard
        let mut a2 = a.clone();
        for v in &mut a2.values {
            *v *= 2.0;
        }
        let t2 = router.admit(&a2).unwrap();
        assert_eq!(t1, t2);
        assert_eq!(router.stats().spin_ups, 1);
        assert_eq!(router.stats().shards_live, 1);
        assert_eq!(router.tenant_of(&a), t1);
        // a different pattern gets its own shard
        let b = gen::grid2d_laplacian(6, 7);
        let t3 = router.admit(&b).unwrap();
        assert_ne!(t1, t3);
        assert_eq!(router.stats().shards_live, 2);
    }

    #[test]
    fn submit_to_unknown_tenant_is_a_clean_error() {
        let router = small_router(2, 4);
        let bogus = TenantId(0x1234);
        assert!(matches!(
            router.submit(bogus, Request::Solve { rhs: vec![1.0] }),
            Err(ServeError::UnknownTenant { tenant: 0x1234 })
        ));
        assert!(matches!(
            router.drain_tenant(bogus),
            Err(ServeError::UnknownTenant { .. })
        ));
    }

    #[test]
    fn full_shard_rejects_with_shard_full_and_counts_it() {
        let router = small_router(2, 2);
        let a = gen::grid2d_laplacian(6, 6);
        let t = router.admit(&a).unwrap();
        let rhs = vec![1.0; a.n_rows()];
        router.submit(t, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap();
        let err = router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap_err();
        assert!(matches!(err, ServeError::ShardFull { capacity: 2, .. }));
        assert_eq!(router.queued(t).unwrap(), 2);
        // draining frees the queue; the rejection was counted per-tenant
        let outcomes = router.drain_tenant(t).unwrap();
        assert_eq!(outcomes.len(), 2);
        router.submit(t, Request::Solve { rhs }).unwrap();
        let stats = router.tenant_stats(t).unwrap();
        assert_eq!(stats.submitted, 3);
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.completed, 2);
    }

    #[test]
    fn eviction_prefers_cache_lru_and_spares_busy_shards() {
        let router = small_router(2, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let c = gen::grid2d_laplacian(7, 7);
        let ta = router.admit(&a).unwrap();
        let tb = router.admit(&b).unwrap();
        // `a` is LRU but busy (queued work); `b` is idle → b is evicted
        router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
        let tc = router.admit(&c).unwrap();
        let stats = router.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.shards_live, 2);
        let live = router.tenants();
        assert!(live.contains(&ta), "busy shard spared");
        assert!(live.contains(&tc));
        assert!(!live.contains(&tb), "idle LRU shard evicted");
        // the busy shard's queued work still drains fine
        let outcomes = router.drain_tenant(ta).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].is_ok());
    }

    #[test]
    fn router_full_when_every_shard_is_busy() {
        let router = small_router(2, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let ta = router.admit(&a).unwrap();
        let tb = router.admit(&b).unwrap();
        router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.submit(tb, Request::Refactorize { values: b.values.clone() }).unwrap();
        let c = gen::grid2d_laplacian(7, 7);
        assert!(matches!(
            router.admit(&c),
            Err(ServeError::RouterFull { max_shards: 2 })
        ));
        // draining any shard makes room again
        router.drain_tenant(ta).unwrap();
        assert!(router.admit(&c).is_ok());
    }

    #[test]
    fn scale_tenant_resizes_and_sheds_low_priority_first() {
        let registry = Arc::new(Registry::new());
        let router = Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards: 2,
                plan_cache_capacity: 4,
                shard_queue: 8,
                registry: Some(registry.clone()),
                ..RouterConfig::default()
            },
        );
        let a = gen::grid2d_laplacian(6, 6);
        let t = router.admit(&a).unwrap();
        router.scale_tenant(t, 2, 8, 4).unwrap();
        let rhs = vec![1.0; 36];
        // low fills to the watermark, then sheds (reported as ShardFull)
        for _ in 0..4 {
            router
                .submit_with_priority(t, Request::Solve { rhs: rhs.clone() }, Priority::Low)
                .unwrap();
        }
        assert!(matches!(
            router.submit_with_priority(t, Request::Solve { rhs: rhs.clone() }, Priority::Low),
            Err(ServeError::ShardFull { capacity: 8, .. })
        ));
        // high still fills to true capacity, then rejects as full
        for _ in 0..4 {
            router.submit(t, Request::Solve { rhs: rhs.clone() }).unwrap();
        }
        assert!(matches!(
            router.submit(t, Request::Solve { rhs }),
            Err(ServeError::ShardFull { capacity: 8, .. })
        ));
        let health = router.health();
        assert_eq!(health.len(), 1);
        assert_eq!(health[0].queue_depth, 8);
        assert_eq!(health[0].queue_capacity, 8);
        assert_eq!(health[0].low_priority_limit, 4);
        assert_eq!(health[0].sessions_target, 2);
        // the two rejection reasons are distinguishable in the registry
        let label = ShardMetrics::label_of(t);
        let by_reason = |reason: &str| {
            registry
                .counter(
                    "sparselu_tenant_rejected_total",
                    "",
                    &[("tenant", label.as_str()), ("reason", reason)],
                )
                .get()
        };
        assert_eq!(by_reason("shed"), 1);
        assert_eq!(by_reason("full"), 1);
        assert_eq!(
            registry
                .counter("sparselu_tenant_submitted_total", "", &[("tenant", label.as_str())])
                .get(),
            8
        );
        // scaling an unknown tenant is a clean error
        assert!(matches!(
            router.scale_tenant(TenantId(0xdead), 1, 1, 1),
            Err(ServeError::UnknownTenant { .. })
        ));
    }

    #[test]
    fn revived_tenant_reuses_the_cached_plan() {
        let router = small_router(1, 4);
        let a = gen::grid2d_laplacian(6, 6);
        let b = gen::grid2d_laplacian(6, 7);
        let ta = router.admit(&a).unwrap();
        let plan_a = router.plan_of(ta).unwrap();
        router.admit(&b).unwrap(); // evicts a's shard (cap 1)
        assert!(matches!(
            router.submit(ta, Request::Solve { rhs: vec![1.0; 36] }),
            Err(ServeError::UnknownTenant { .. })
        ));
        let misses_before = router.stats().cache_misses;
        let ta2 = router.admit(&a).unwrap(); // revival
        assert_eq!(ta, ta2, "tenant id is stable across eviction");
        let stats = router.stats();
        assert_eq!(stats.revivals, 1);
        assert_eq!(stats.cache_misses, misses_before, "revival hit the plan cache");
        assert!(
            Arc::ptr_eq(&plan_a, &router.plan_of(ta2).unwrap()),
            "the revived shard shares the original plan"
        );
    }

    /// A small pattern missing the diagonal entry at `row`.
    fn singular_pattern(n: usize, row: usize) -> crate::sparse::Csc {
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            if i != row {
                coo.push(i, i, 4.0);
            }
        }
        coo.push(0, row, 1.0);
        coo.push(row, (row + 1) % n, 1.0);
        coo.to_csc()
    }

    #[test]
    fn structurally_singular_admission_fails_cleanly_and_router_survives() {
        let router = small_router(4, 8);
        let good = gen::grid2d_laplacian(6, 6);
        let tg = router.admit(&good).unwrap();
        let bad = singular_pattern(5, 2);
        let err = router.admit(&bad).unwrap_err();
        assert!(matches!(
            err,
            ServeError::Factor(FactorError::StructurallySingular { row: 2 })
        ));
        assert_eq!(router.stats().shards_live, 1, "no shard for the bad pattern");
        // the router keeps serving the good tenant
        router.submit(tg, Request::Refactorize { values: good.values.clone() }).unwrap();
        let outcomes = router.drain_tenant(tg).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].is_ok());
    }

    #[test]
    fn drift_storm_spins_up_background_tenant_and_reroutes() {
        let router = small_router(4, 8);
        let a = gen::grid2d_laplacian(6, 6);
        let ta = router.admit(&a).unwrap();
        router.submit(ta, Request::Refactorize { values: a.values.clone() }).unwrap();
        router.drain_tenant(ta).unwrap();
        // an in-pattern stamp by coordinates routes normally
        let same = router.submit_stamp_coords(ta, &a, &[(0, 0, 5.0)]).unwrap();
        assert_eq!(same, ta);
        assert!(router.drain_tenant(ta).unwrap()[0].is_ok());
        // the client's matrix drifts: strikes below the threshold are
        // rejected with the running count
        let b = gen::grid2d_laplacian(6, 7);
        let coords = [(0usize, 0usize, 9.0f64)];
        for strike in 1..3 {
            match router.submit_stamp_coords(ta, &b, &coords).unwrap_err() {
                ServeError::PatternDrift { tenant, drifted, strikes } => {
                    assert_eq!(tenant, ta.0);
                    assert_eq!(drifted, router.tenant_of(&b).0);
                    assert_eq!(strikes, strike);
                }
                other => panic!("expected PatternDrift, got {other}"),
            }
        }
        // the third drifted stamp crosses the default threshold: the
        // drifted pattern spins up in the background and the request is
        // re-routed as the new tenant's seeding refactorize
        let tb = router.submit_stamp_coords(ta, &b, &coords).unwrap();
        assert_eq!(tb, router.tenant_of(&b));
        assert_ne!(tb, ta);
        assert_eq!(router.stats().speculative_builds, 1);
        // draining the new tenant blocks on the background build
        // internally, then serves — with the stamp folded in
        let outcomes = router.drain_tenant(tb).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].is_ok());
        let plan = router.plan_of(tb).unwrap();
        assert_eq!(plan.fingerprint(), b.pattern_fingerprint());
        // the original tenant still serves its own pattern
        router.submit(ta, Request::Solve { rhs: vec![1.0; 36] }).unwrap();
        assert!(router.drain_tenant(ta).unwrap()[0].is_ok());
    }

    #[test]
    fn panicking_background_build_fails_requests_and_is_counted() {
        let registry = Arc::new(Registry::new());
        let router = Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards: 4,
                plan_cache_capacity: 8,
                shard_queue: 8,
                registry: Some(registry.clone()),
                ..RouterConfig::default()
            },
        );
        // a non-square pattern trips the square-systems assert inside
        // the plan pipeline: a genuine panic on the builder thread
        let mut coo = crate::sparse::Coo::new(4, 5);
        for i in 0..4 {
            coo.push(i, i, 2.0);
        }
        coo.push(0, 4, 1.0);
        let rect = coo.to_csc();
        let t = router.admit_background(&rect).unwrap();
        router.submit(t, Request::Refactorize { values: rect.values.clone() }).unwrap();
        // the panic resolves the pending build: queued requests fail
        // per-request instead of hanging on an unpublished slot
        let outcomes = router.drain_tenant(t).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(matches!(outcomes[0], Err(ServeError::Factor(FactorError::TaskPanic))));
        assert_eq!(
            registry.counter("sparselu_plan_build_panics_total", "", &[]).get(),
            1,
            "the panic is visible on the scrape surface"
        );
        // the builder thread was reaped, not left detached
        assert!(router.shard_of(t).unwrap().build_thread.lock().unwrap().is_none());
        // the router keeps serving other tenants
        let good = gen::grid2d_laplacian(5, 5);
        let tg = router.admit(&good).unwrap();
        router.submit(tg, Request::Refactorize { values: good.values.clone() }).unwrap();
        assert!(router.drain_tenant(tg).unwrap()[0].is_ok());
    }

    #[test]
    fn plan_build_metrics_break_down_by_phase() {
        let registry = Arc::new(Registry::new());
        let router = Router::new(
            SolveOptions::ours(1),
            RouterConfig {
                max_shards: 2,
                plan_cache_capacity: 4,
                shard_queue: 4,
                registry: Some(registry.clone()),
                ..RouterConfig::default()
            },
        );
        let a = gen::grid2d_laplacian(6, 6);
        router.admit(&a).unwrap();
        let count_of = |phase: &str| {
            let labels = [("phase", phase)];
            registry
                .histogram("sparselu_plan_build_seconds", "", &labels, &obs::BUILD_BUCKETS)
                .snapshot()
                .count()
        };
        for phase in ["total", "ordering", "symbolic", "blocking", "reach"] {
            assert_eq!(count_of(phase), 1, "one sample for phase {phase}");
        }
        obs::validate(&registry.render()).unwrap();
        // a cache hit records no new build samples
        router.admit(&a).unwrap();
        assert_eq!(count_of("total"), 1);
    }

    #[test]
    fn background_build_failure_fails_queued_requests_not_the_process() {
        let router = small_router(4, 8);
        let bad = singular_pattern(4, 1);
        let t = router.admit_background(&bad).unwrap();
        // submissions are accepted while the build is pending…
        router.submit(t, Request::Refactorize { values: bad.values.clone() }).unwrap();
        // …and fail per-request once the build resolves singular
        let outcomes = router.drain_tenant(t).unwrap();
        assert_eq!(outcomes.len(), 1);
        assert!(matches!(
            outcomes[0],
            Err(ServeError::Factor(FactorError::StructurallySingular { row: 1 }))
        ));
        // the shard and the router both survive
        assert!(router.drain_tenant(t).unwrap().is_empty());
        let good = gen::grid2d_laplacian(5, 5);
        let tg = router.admit(&good).unwrap();
        assert_ne!(t, tg);
        router.submit(tg, Request::Refactorize { values: good.values.clone() }).unwrap();
        assert!(router.drain_tenant(tg).unwrap()[0].is_ok());
    }
}
