//! Solver serving — the multi-client layer over the `session` subsystem.
//!
//! Everything the paper contributes is structure-only, which makes the
//! **plan** the unit of scale for serving: one `Arc<FactorPlan>` carries
//! the ordering, symbolic pattern, irregular blocking, task DAG and
//! scatter map for a sparsity pattern, and any number of concurrent
//! clients re-factorize *values* against it. This module turns the
//! single-session library into that service:
//!
//! * [`SessionPool`] — N [`crate::session::SolverSession`]s bound to one
//!   shared plan, with RAII checkout/checkin and lazy growth: concurrent
//!   clients refactorize/solve without re-planning and without
//!   allocating blocked storage per request.
//! * [`Batcher`] — a bounded request queue that coalesces consecutive
//!   solve requests into one batched multi-RHS sweep, routes device
//!   stamps through [`crate::session::SolverSession::estimate_partial`]
//!   (pruned partial path vs full refactorize), and returns clean
//!   [`ServeError`]s for malformed client input.
//! * [`persist`] — versioned, checksummed binary serialization of
//!   [`crate::session::FactorPlan`] plus
//!   [`crate::session::PlanCache::warm_from_dir`], so a cold start costs
//!   one disk read instead of ordering + symbolic + blocking.
//! * [`Router`] — the **multi-tenant** front-end: requests are routed by
//!   sparsity-pattern fingerprint to a per-pattern *shard* (one shared
//!   plan + its own [`SessionPool`] + its own [`Batcher`]), shards drain
//!   concurrently on a worker pool, full shard queues reject with a
//!   clean [`ServeError::ShardFull`], and idle shards are evicted (and
//!   later revived) following the [`crate::session::PlanCache`]'s LRU
//!   order.
//! Every layer publishes to a [`crate::obs::Registry`]
//! ([`RouterConfig::registry`]): per-tenant queue/latency/batch series,
//! pool occupancy, plan-cache and executor counters — scrapeable via
//! [`crate::obs::MetricsServer`] and actuated on by the SLO-driven
//! [`crate::obs::Autoscaler`] through [`Router::health`] /
//! [`Router::scale_tenant`] (session-pool resize, queue rebound, and
//! [`Priority::Low`] load shedding at admission).
//!
//! * [`loadgen`] — a closed-loop, K-client load generator over a
//!   full/stamp/solve scenario mix — single-pool
//!   ([`loadgen::run`]) and multi-tenant ([`loadgen::run_multi`], K
//!   clients spread over M patterns through a [`Router`]) — emitting the
//!   `BENCH_serve.json` throughput + p50/p99 report (`repro
//!   serve-bench`).
//!
//! ## Serving flow
//!
//! ```no_run
//! use sparselu::serve::{persist, Batcher, Request, SessionPool};
//! use sparselu::session::PlanCache;
//! use sparselu::solver::SolveOptions;
//! use sparselu::sparse::gen;
//! use std::path::Path;
//!
//! let a = gen::circuit_bbd(gen::CircuitParams::default());
//! let opts = SolveOptions::ours(4);
//!
//! // warm start: plans persisted by a previous process load in one read
//! let mut cache = PlanCache::new(8);
//! let warm = cache.warm_from_dir(Path::new("plans")).unwrap();
//! println!("{} plans warmed from disk", warm.loaded);
//! let plan = cache.get_or_build(&a, &opts).unwrap(); // hit if persisted before
//! persist::save_plan_to_dir(&plan, Path::new("plans")).unwrap();
//!
//! // share the plan across a session pool; batch one client's requests
//! let pool = SessionPool::new(plan, 4);
//! let mut session = pool.checkout();
//! session.refactorize(&a.values).unwrap();
//! let mut batcher = Batcher::new(64);
//! for _ in 0..3 {
//!     batcher.submit(Request::Solve { rhs: vec![1.0; a.n_rows()] }).unwrap();
//! }
//! let outcomes = batcher.drain(&mut session); // one solve_many sweep,
//! // one Ok/Err outcome per request — a bad request never poisons others
//! assert_eq!(outcomes[0].as_ref().unwrap().batch_size, 3);
//! ```

pub mod batcher;
pub mod loadgen;
pub mod persist;
pub mod pool;
pub mod router;

pub use batcher::{Batcher, Priority, Request, RequestKind, ServeError, ServeReport};
pub use loadgen::{
    LoadgenConfig, LoadgenReport, MultiTenantConfig, MultiTenantReport, ScenarioMix, TenantBench,
};
pub use persist::{load_plan, save_plan, save_plan_to_dir, PersistError, WarmReport};
pub use pool::{PooledSession, PoolMetrics, PoolStats, SessionPool};
pub use router::{Router, RouterConfig, RouterStats, TenantHealth, TenantId, TenantStats};
