//! [`Batcher`] — a bounded request queue that coalesces solve requests
//! into batched multi-RHS calls and routes device stamps through the
//! cheapest re-factorization path.
//!
//! Decoupling request *arrival* from task *execution* is where
//! multi-client factorization throughput comes from (the asynchronous
//! task-based solver literature): clients [`Batcher::submit`] without
//! holding a session, and a worker holding a checked-out session
//! [`Batcher::drain`]s the queue, which
//!
//! * coalesces each **consecutive run of [`Request::Solve`]s** into one
//!   [`crate::session::SolverSession::solve_many`] call (the factor
//!   blocks are traversed once for the whole batch);
//! * coalesces each **consecutive run of [`Request::Stamp`]s** into one
//!   merged [`ChangeSet`] — change-set batching across timesteps: one
//!   dirty-block closure and one pruned replay serve the whole run, and
//!   because later updates win per index the merged factors are
//!   bit-identical to stamping each set one at a time
//!   ([`ChangeSet::extend_from`]);
//! * routes each (merged) stamp through
//!   [`crate::session::SolverSession::estimate_partial`]: small closures
//!   go down the pruned [`refactorize_partial`] path, closures above the
//!   threshold fall back to a full numeric refactorize (whose
//!   whole-matrix scatter is cheaper than block-by-block rescatter once
//!   most blocks are dirty anyway);
//! * rejects malformed client input ([`ServeError`]) instead of
//!   panicking — a serving process must outlive any one request.
//!
//! [`refactorize_partial`]: crate::session::SolverSession::refactorize_partial

use crate::numeric::factor::FactorError;
use crate::numeric::Precision;
use crate::session::{ChangeSet, RefineError, SolverSession};
use std::collections::VecDeque;
use std::time::{Duration, Instant};

/// One client request against a session's current plan/pattern.
#[derive(Clone, Debug)]
pub enum Request {
    /// Full numeric re-factorization to a new value vector (CSC order of
    /// the planned pattern).
    Refactorize { values: Vec<f64> },
    /// Incremental device stamp: a sparse set of value updates.
    Stamp { changes: ChangeSet },
    /// Solve `A x = b` against the current factors.
    Solve { rhs: Vec<f64> },
    /// Solve `A x = b` on a [`Precision::Mixed`] shard: triangular replay
    /// against the f32 factors plus f64 iterative refinement
    /// ([`SolverSession::solve_refined`]). Valid only on batchers
    /// configured with [`Batcher::with_precision`]`(Mixed)`; rejected
    /// with [`ServeError::PrecisionMismatch`] elsewhere.
    SolveMixed { rhs: Vec<f64> },
}

/// Request discriminant carried on reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestKind {
    Refactorize,
    Stamp,
    Solve,
    SolveMixed,
}

/// Admission priority class. Priority is **admission-only**: it decides
/// whether a request gets into the queue when the shard is saturated
/// (low class is rejected first, at the shedding watermark instead of
/// the full capacity), never the order requests execute in. Admitted
/// requests run in submission order regardless of class, so results for
/// admitted requests are bit-identical with shedding on or off.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Priority {
    /// Interactive / SLO-bound traffic: admitted up to full capacity.
    #[default]
    High,
    /// Best-effort traffic (bulk sweeps, speculative timesteps): shed
    /// first when the autoscaler detects saturation.
    Low,
}

/// Serving failure — returned to the client, never a process abort.
///
/// `Clone` so one failed coalesced execution can be reported to every
/// request that rode in it.
#[derive(Clone, Debug)]
pub enum ServeError {
    /// The bounded queue is at capacity; the client must back off.
    QueueFull { capacity: usize },
    /// A tenant shard's bounded queue is at capacity — the multi-tenant
    /// form of [`ServeError::QueueFull`], carrying the tenant key so a
    /// client talking to a [`crate::serve::Router`] knows *which* of its
    /// patterns is backed up.
    ShardFull { tenant: u64, capacity: usize },
    /// A request addressed a tenant the router has no live shard for
    /// (never admitted, or evicted — re-admit the pattern to revive it).
    UnknownTenant { tenant: u64 },
    /// The router is at its shard cap and every live shard has queued or
    /// in-flight work, so none can be evicted to make room.
    RouterFull { max_shards: usize },
    /// A solve or stamp arrived before any successful factorization
    /// seeded the session's factors.
    NotFactored,
    /// A value vector whose length does not match the planned pattern.
    WrongValueCount { got: usize, want: usize },
    /// A stamp addressed a value index past the planned pattern's nnz.
    StampOutOfRange { index: usize, nnz: usize },
    /// The factorization itself failed (zero pivot, out-of-pattern
    /// stamp, …).
    Factor(FactorError),
    /// A solve request's precision mode does not match the serving
    /// session's: a plain [`Request::Solve`] on a mixed-precision shard
    /// (its f64 storage holds no current factors) or a
    /// [`Request::SolveMixed`] on a full-precision shard (no f32 factors
    /// exist). Routing is per-shard, so the client should resubmit to a
    /// shard configured for the precision it wants.
    PrecisionMismatch { request_needs: Precision, session_at: Precision },
    /// Mixed-precision iterative refinement failed to converge — the
    /// system is too ill-conditioned for f32 factors. The client should
    /// retry the solve against a [`Precision::Full`] shard.
    Refine(RefineError),
    /// A stamp's coordinates no longer match the tenant's pattern — the
    /// client's matrix has drifted. After `strikes` reaches the router's
    /// drift-storm threshold a background plan build for the drifted
    /// pattern starts and the client is transparently re-routed; until
    /// then the request is rejected with this error so the client can
    /// retry against the original tenant or resubmit the full matrix.
    PatternDrift { tenant: u64, drifted: u64, strikes: usize },
    /// The request's deadline passed while it sat in the queue: the
    /// batch it would have ridden in started `late_by` too late. The
    /// work was **not** executed — a deadline-expired request costs the
    /// server nothing but the queue slot it held.
    DeadlineExceeded { late_by: Duration },
    /// No pooled session became idle within the drain's
    /// [`crate::serve::SessionPool::checkout_timeout`] window; the
    /// request was failed instead of waiting unboundedly behind a
    /// stalled or leaked checkout.
    PoolTimeout { waited: Duration },
    /// The tenant's shard is quarantined: a factorization produced
    /// non-finite values ([`FactorError::NonFinite`]) and the router is
    /// rebuilding the shard's sessions in the background. Fail-fast —
    /// retry after the rebuild revives the tenant (watch
    /// [`crate::serve::Router::health`]).
    TenantQuarantined { tenant: u64 },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::QueueFull { capacity } => {
                write!(f, "request queue full (capacity {capacity})")
            }
            ServeError::ShardFull { tenant, capacity } => {
                write!(f, "shard for tenant {tenant:#018x} full (capacity {capacity})")
            }
            ServeError::UnknownTenant { tenant } => {
                write!(f, "no live shard for tenant {tenant:#018x} (admit the pattern first)")
            }
            ServeError::RouterFull { max_shards } => {
                write!(f, "router at shard capacity ({max_shards}) with no evictable shard")
            }
            ServeError::NotFactored => {
                write!(f, "no factors yet: a full refactorize must precede solves/stamps")
            }
            ServeError::WrongValueCount { got, want } => {
                write!(f, "value vector has {got} entries, planned pattern has {want}")
            }
            ServeError::StampOutOfRange { index, nnz } => {
                write!(f, "stamp value index {index} out of range (pattern nnz = {nnz})")
            }
            ServeError::Factor(e) => write!(f, "factorization failed: {e}"),
            ServeError::PrecisionMismatch { request_needs, session_at } => write!(
                f,
                "request needs a {request_needs:?}-precision session, shard serves \
                 {session_at:?}"
            ),
            ServeError::Refine(e) => write!(f, "{e}"),
            ServeError::PatternDrift { tenant, drifted, strikes } => {
                write!(
                    f,
                    "stamp pattern drifted from tenant {tenant:#018x} toward \
                     {drifted:#018x} ({strikes} strikes)"
                )
            }
            ServeError::DeadlineExceeded { late_by } => {
                write!(
                    f,
                    "request deadline exceeded: execution would have started \
                     {:.3}ms late",
                    late_by.as_secs_f64() * 1e3
                )
            }
            ServeError::PoolTimeout { waited } => {
                write!(
                    f,
                    "no pooled session became idle within {:.3}ms",
                    waited.as_secs_f64() * 1e3
                )
            }
            ServeError::TenantQuarantined { tenant } => {
                write!(
                    f,
                    "tenant {tenant:#018x} is quarantined (non-finite factors); \
                     a background rebuild is under way — retry later"
                )
            }
        }
    }
}

impl std::error::Error for ServeError {}

impl From<FactorError> for ServeError {
    fn from(e: FactorError) -> Self {
        ServeError::Factor(e)
    }
}

/// Per-request execution report.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub kind: RequestKind,
    /// Seconds the request sat in the queue before its batch started
    /// executing.
    pub queue_seconds: f64,
    /// Seconds the batch this request rode in spent executing (shared by
    /// every member of a coalesced run). `queue_seconds + exec_seconds`
    /// is the request's server-side latency.
    pub exec_seconds: f64,
    /// Number of requests executed together with this one (solve or
    /// stamp coalescing run length; 1 for refactorize).
    pub batch_size: usize,
    /// DAG tasks executed on behalf of this request (0 for solves; for a
    /// coalesced stamp run the merged execution's count is attributed to
    /// the run's **first** report only, so summing over reports never
    /// double-counts work).
    pub tasks_executed: usize,
    /// DAG tasks skipped by reachability pruning (0 for solves and full
    /// refactorizes; attributed like `tasks_executed`).
    pub tasks_skipped: usize,
    /// Stamp requests: whether the batcher chose the pruned partial path
    /// (`false` = estimator sent it down the full refactorize).
    pub went_partial: bool,
    /// Solve requests: the solution vector.
    pub solution: Option<Vec<f64>>,
    /// [`crate::obs::trace`] correlation id of the batch this request
    /// rode in: every task event the batch's DAG runs record carries the
    /// same id, so a slow request can be matched to its exact tasks in a
    /// `/trace` export. `0` when tracing was off at execution time.
    pub trace_id: u64,
    /// [`Request::SolveMixed`] only: iterative-refinement corrections
    /// applied to reach the accuracy target (0 = the raw mixed solve
    /// already met it). `None` for every other request kind — and for a
    /// mixed solve rescued by the full-precision fallback (`degraded`
    /// is set instead; no refinement ran).
    pub refine_iterations: Option<usize>,
    /// The request succeeded only through the degradation ladder: a
    /// diverging mixed-precision solve was transparently re-run at full
    /// precision, or a faulted partial refactorize was retried as a
    /// full refactorize after block reset. The result is still exact —
    /// `degraded` flags that the fast path failed and the slow path
    /// paid for it (mirrored as `sparselu_degraded_total`).
    pub degraded: bool,
}

/// Bounded, coalescing request queue over one session.
///
/// The batcher itself is single-threaded by design — one batcher drains
/// into one checked-out session; concurrency comes from running several
/// batcher+session pairs against a [`crate::serve::SessionPool`].
pub struct Batcher {
    capacity: usize,
    /// Admission watermark for [`Priority::Low`] requests. Equal to
    /// `capacity` when shedding is off; the autoscaler lowers it under
    /// saturation so best-effort traffic is rejected before the queue
    /// can fill against high-priority clients.
    low_limit: usize,
    /// Stamps whose estimated run fraction exceeds this go down the full
    /// refactorize path instead of the pruned partial path.
    partial_threshold: f64,
    /// Coalesce consecutive stamp requests into one merged change set
    /// (one dirty-block closure, one pruned replay) before executing.
    coalesce_stamps: bool,
    /// Factorization precision this batcher's drains run sessions at.
    /// [`Batcher::drain`] aligns the checked-out session to it before
    /// executing anything, so every session of a shard's pool converges
    /// to the shard's configured mode.
    precision: Precision,
    queue: VecDeque<Queued>,
    /// Executions rescued by the degradation ladder since construction
    /// (one per absorbed failure, not per coalesced rider) — see
    /// [`Batcher::degraded_runs`].
    degraded_runs: u64,
}

/// One admitted request: payload, admission instant (queue-latency
/// accounting) and optional expiry (deadline enforcement at drain).
struct Queued {
    request: Request,
    submitted: Instant,
    deadline: Option<Instant>,
}

impl Queued {
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now > d)
    }
}

impl Batcher {
    /// Queue bounded at `capacity` requests, with the default routing
    /// threshold (stamps re-running more than half the DAG go full),
    /// stamp coalescing enabled and no priority shedding.
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "Batcher needs capacity >= 1");
        Self {
            capacity,
            low_limit: capacity,
            partial_threshold: 0.5,
            coalesce_stamps: true,
            precision: Precision::Full,
            queue: VecDeque::new(),
            degraded_runs: 0,
        }
    }

    /// Serve at `precision`. Under [`Precision::Mixed`] every
    /// refactorize/stamp runs the f32 kernels (half the value-memory
    /// traffic on the bandwidth-bound replay path) and clients solve via
    /// [`Request::SolveMixed`], which recovers full f64 accuracy by
    /// iterative refinement. Plain [`Request::Solve`]s are rejected with
    /// [`ServeError::PrecisionMismatch`] on a mixed batcher (there are
    /// no f64 factors to solve against), and vice versa.
    pub fn with_precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// The precision this batcher drains sessions at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Override the partial-vs-full routing threshold (fraction of DAG
    /// tasks; `1.0` always goes partial, `0.0` always full — both still
    /// bit-identical, only the execution path differs).
    pub fn with_partial_threshold(mut self, threshold: f64) -> Self {
        assert!((0.0..=1.0).contains(&threshold), "threshold must be in [0, 1]");
        self.partial_threshold = threshold;
        self
    }

    /// Enable/disable change-set batching across timesteps (coalescing
    /// consecutive [`Request::Stamp`]s into one merged
    /// [`ChangeSet`] — see [`ChangeSet::extend_from`] for why the merge
    /// is exact). On by default; turn off to force one partial
    /// refactorize per stamp (e.g. when per-stamp task counts matter
    /// more than throughput).
    pub fn with_stamp_coalescing(mut self, coalesce: bool) -> Self {
        self.coalesce_stamps = coalesce;
        self
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Current [`Priority::Low`] admission watermark (`== capacity()`
    /// when shedding is off).
    pub fn low_priority_limit(&self) -> usize {
        self.low_limit
    }

    /// Re-bound the queue at runtime (autoscaler control knob). Already
    /// queued requests are never dropped — a shrink below the current
    /// length only stops *new* admissions until the queue drains down.
    /// A shedding watermark above the new capacity is clamped to it.
    pub fn set_capacity(&mut self, capacity: usize) {
        assert!(capacity > 0, "Batcher needs capacity >= 1");
        let was_off = self.low_limit == self.capacity;
        self.capacity = capacity;
        // "no shedding" tracks the capacity; an explicit watermark clamps
        self.low_limit = if was_off { capacity } else { self.low_limit.min(capacity) };
    }

    /// Set the [`Priority::Low`] admission watermark (clamped to
    /// capacity). `set_low_priority_limit(capacity())` turns shedding
    /// off.
    pub fn set_low_priority_limit(&mut self, limit: usize) {
        self.low_limit = limit.min(self.capacity);
    }

    /// Enqueue a request at [`Priority::High`], rejecting it when the
    /// queue is at capacity.
    pub fn submit(&mut self, request: Request) -> Result<(), ServeError> {
        self.submit_opts(request, Priority::High, None)
    }

    /// Enqueue a request under an explicit priority class. High is
    /// admitted up to `capacity`; low only while the queue is below the
    /// shedding watermark. Both rejections are
    /// [`ServeError::QueueFull`] — a shed client backs off exactly like
    /// a client hitting a genuinely full queue.
    pub fn submit_with_priority(
        &mut self,
        request: Request,
        priority: Priority,
    ) -> Result<(), ServeError> {
        self.submit_opts(request, priority, None)
    }

    /// Enqueue a request with a deadline: if `deadline` passes before
    /// the drain reaches it, the request fails with
    /// [`ServeError::DeadlineExceeded`] **without executing** — bounded
    /// staleness for interactive clients that would rather retry than
    /// receive a late answer.
    ///
    /// ```
    /// use sparselu::serve::{Batcher, Request, ServeError};
    /// use sparselu::session::{FactorPlan, SolverSession};
    /// use sparselu::solver::SolveOptions;
    /// use sparselu::sparse::gen;
    /// use std::sync::Arc;
    /// use std::time::{Duration, Instant};
    ///
    /// let a = gen::grid2d_laplacian(4, 4);
    /// let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
    /// let mut session = SolverSession::from_plan(plan);
    /// session.refactorize(&a.values).unwrap();
    ///
    /// let mut batcher = Batcher::new(8);
    /// let rhs = vec![1.0; a.n_rows()];
    /// batcher
    ///     .submit_with_deadline(Request::Solve { rhs: rhs.clone() }, Instant::now())
    ///     .unwrap();
    /// batcher
    ///     .submit_with_deadline(
    ///         Request::Solve { rhs },
    ///         Instant::now() + Duration::from_secs(60),
    ///     )
    ///     .unwrap();
    /// std::thread::sleep(Duration::from_millis(2)); // first deadline passes
    ///
    /// let outcomes = batcher.drain(&mut session);
    /// assert!(matches!(outcomes[0], Err(ServeError::DeadlineExceeded { .. })));
    /// assert!(outcomes[1].is_ok(), "a live deadline never blocks execution");
    /// ```
    pub fn submit_with_deadline(
        &mut self,
        request: Request,
        deadline: Instant,
    ) -> Result<(), ServeError> {
        self.submit_opts(request, Priority::High, Some(deadline))
    }

    /// Full-control admission: priority class plus optional deadline.
    pub fn submit_opts(
        &mut self,
        request: Request,
        priority: Priority,
        deadline: Option<Instant>,
    ) -> Result<(), ServeError> {
        let limit = match priority {
            Priority::High => self.capacity,
            Priority::Low => self.low_limit,
        };
        if self.queue.len() >= limit {
            return Err(ServeError::QueueFull { capacity: self.capacity });
        }
        self.queue.push_back(Queued { request, submitted: Instant::now(), deadline });
        Ok(())
    }

    /// Executions the degradation ladder rescued since this batcher was
    /// built: one per absorbed fast-path failure (a diverged mixed
    /// solve re-run at full precision, a faulted partial refactorize
    /// retried full), regardless of how many coalesced riders shared
    /// the rescued execution. `injected == surfaced + rescued` is the
    /// chaos suite's balance invariant.
    pub fn degraded_runs(&self) -> u64 {
        self.degraded_runs
    }

    /// Fail every queued request with a clone of `err`, in submission
    /// order, consuming the queue. The router uses this when a shard's
    /// plan build fails (e.g. a structurally singular pattern): the
    /// clients get per-request errors and the shard — and the process —
    /// survive.
    pub fn fail_all(&mut self, err: &ServeError) -> Vec<Result<ServeReport, ServeError>> {
        let mut outcomes = Vec::with_capacity(self.queue.len());
        while self.queue.pop_front().is_some() {
            outcomes.push(Err(err.clone()));
        }
        outcomes
    }

    /// Execute every queued request against `session`, in submission
    /// order, returning one outcome per request — the queue is always
    /// fully consumed and one malformed or failing request can never
    /// swallow its neighbors' work or results.
    ///
    /// Only *valid* consecutive solves coalesce into one multi-RHS
    /// sweep; an invalid solve (wrong RHS length, no factors yet) gets
    /// its own `Err` entry and the requests around it are served
    /// normally.
    pub fn drain(
        &mut self,
        session: &mut SolverSession<'_>,
    ) -> Vec<Result<ServeReport, ServeError>> {
        // align the checked-out session to the shard's configured
        // precision before executing anything. A flip invalidates the
        // session's factors (the other precision's storage is stale), so
        // the first request after a reconfiguration must be a
        // Refactorize — solves and stamps before one get NotFactored.
        if session.precision() != self.precision {
            session.set_precision(self.precision);
        }
        let mut outcomes = Vec::with_capacity(self.queue.len());
        while let Some(q) = self.queue.pop_front() {
            // deadline enforcement: an expired request is failed here,
            // before any execution — it cost the server only its slot
            let now = Instant::now();
            if q.expired(now) {
                let deadline = q.deadline.expect("expired() implies a deadline");
                outcomes.push(Err(ServeError::DeadlineExceeded { late_by: now - deadline }));
                continue;
            }
            let Queued { request, submitted, .. } = q;
            // one trace id per executed batch: every DAG task the batch
            // runs records it, and every report that rode in the batch
            // carries it (0 when tracing is off — no id is minted)
            let trace_id = if crate::obs::trace::enabled() {
                let id = crate::obs::trace::next_trace_id();
                session.set_trace_id(id);
                id
            } else {
                0
            };
            match request {
                Request::Solve { rhs } => {
                    let n = session.plan().n();
                    if self.precision != Precision::Full {
                        outcomes.push(Err(ServeError::PrecisionMismatch {
                            request_needs: Precision::Full,
                            session_at: self.precision,
                        }));
                        continue;
                    }
                    if !session.is_factored() {
                        outcomes.push(Err(ServeError::NotFactored));
                        continue;
                    }
                    if rhs.len() != n {
                        outcomes.push(Err(ServeError::WrongValueCount {
                            got: rhs.len(),
                            want: n,
                        }));
                        continue;
                    }
                    // coalesce the following consecutive *valid* solves
                    // into one batched multi-RHS sweep; an invalid one
                    // ends the run and is handled on its own next turn
                    let mut batch = vec![rhs];
                    let mut waits = vec![submitted];
                    loop {
                        // only a *valid, unexpired* solve extends the
                        // run; anything else (including an expired
                        // deadline) breaks it and is handled on its own
                        // next turn
                        match self.queue.front() {
                            Some(f) if !f.expired(Instant::now()) => match &f.request {
                                Request::Solve { rhs } if rhs.len() == n => {}
                                _ => break,
                            },
                            _ => break,
                        }
                        let Some(Queued { request: Request::Solve { rhs }, submitted: t, .. }) =
                            self.queue.pop_front()
                        else {
                            unreachable!("front() just matched a solve");
                        };
                        batch.push(rhs);
                        waits.push(t);
                    }
                    let start = Instant::now();
                    let xs = session.solve_many(&batch);
                    let exec_seconds = start.elapsed().as_secs_f64();
                    let batch_size = batch.len();
                    for (x, t) in xs.into_iter().zip(waits) {
                        outcomes.push(Ok(ServeReport {
                            kind: RequestKind::Solve,
                            queue_seconds: start.duration_since(t).as_secs_f64(),
                            exec_seconds,
                            batch_size,
                            tasks_executed: 0,
                            tasks_skipped: 0,
                            went_partial: false,
                            solution: Some(x),
                            trace_id,
                            refine_iterations: None,
                            degraded: false,
                        }));
                    }
                }
                Request::SolveMixed { rhs } => {
                    // no multi-RHS coalescing here: refinement is a
                    // per-RHS fixed-point iteration (each right-hand side
                    // converges in its own number of corrections), and
                    // the per-solve residual SpMV dominates the shared
                    // pattern-walk savings batching would buy
                    if self.precision != Precision::Mixed {
                        outcomes.push(Err(ServeError::PrecisionMismatch {
                            request_needs: Precision::Mixed,
                            session_at: self.precision,
                        }));
                        continue;
                    }
                    if !session.is_factored() {
                        outcomes.push(Err(ServeError::NotFactored));
                        continue;
                    }
                    let n = session.plan().n();
                    if rhs.len() != n {
                        outcomes.push(Err(ServeError::WrongValueCount {
                            got: rhs.len(),
                            want: n,
                        }));
                        continue;
                    }
                    let start = Instant::now();
                    let result = session.solve_refined(&rhs);
                    let outcome = match result {
                        Ok(refined) => Ok(ServeReport {
                            kind: RequestKind::SolveMixed,
                            queue_seconds: start.duration_since(submitted).as_secs_f64(),
                            exec_seconds: start.elapsed().as_secs_f64(),
                            batch_size: 1,
                            tasks_executed: 0,
                            tasks_skipped: 0,
                            went_partial: false,
                            solution: Some(refined.x),
                            trace_id,
                            refine_iterations: Some(refined.iterations),
                            degraded: false,
                        }),
                        Err(RefineError::Diverged { .. }) => {
                            // degradation ladder: the f32 factors carry
                            // no usable correction for this system —
                            // transparently re-run at full precision
                            // instead of bouncing the client to another
                            // shard. One rung, never recursive.
                            self.degraded_runs += 1;
                            let values = session.current_values().to_vec();
                            session.set_precision(Precision::Full);
                            let rescued = session
                                .refactorize(&values)
                                .map(|_| session.solve(&rhs));
                            // restore the shard's configured mixed mode
                            // so the rest of the queue (and future
                            // drains) find live f32 factors
                            session.set_precision(Precision::Mixed);
                            if session.refactorize(&values).is_err() {
                                // the restore failed (e.g. another
                                // injected fault): the request already
                                // has its answer, so the failure is
                                // absorbed — counted, keeping the
                                // injected == surfaced + rescued
                                // balance exact
                                self.degraded_runs += 1;
                            }
                            match rescued {
                                Ok(x) => Ok(ServeReport {
                                    kind: RequestKind::SolveMixed,
                                    queue_seconds: start
                                        .duration_since(submitted)
                                        .as_secs_f64(),
                                    exec_seconds: start.elapsed().as_secs_f64(),
                                    batch_size: 1,
                                    tasks_executed: 0,
                                    tasks_skipped: 0,
                                    went_partial: false,
                                    solution: Some(x),
                                    trace_id,
                                    refine_iterations: None,
                                    degraded: true,
                                }),
                                Err(e) => Err(ServeError::Factor(e)),
                            }
                        }
                    };
                    outcomes.push(outcome);
                }
                Request::Refactorize { values } => {
                    let want = session.plan().nnz_a();
                    if values.len() != want {
                        outcomes.push(Err(ServeError::WrongValueCount {
                            got: values.len(),
                            want,
                        }));
                        continue;
                    }
                    let start = Instant::now();
                    let result = session.refactorize(&values);
                    let exec_seconds = start.elapsed().as_secs_f64();
                    let outcome = result.map(|rep| ServeReport {
                        kind: RequestKind::Refactorize,
                        queue_seconds: start.duration_since(submitted).as_secs_f64(),
                        exec_seconds,
                        batch_size: 1,
                        tasks_executed: rep.tasks_executed,
                        tasks_skipped: rep.tasks_skipped,
                        went_partial: false,
                        solution: None,
                        trace_id,
                        refine_iterations: None,
                        degraded: false,
                    });
                    outcomes.push(outcome.map_err(ServeError::from));
                }
                Request::Stamp { changes } => {
                    if !session.is_factored() {
                        outcomes.push(Err(ServeError::NotFactored));
                        continue;
                    }
                    let nnz = session.plan().nnz_a();
                    if let Some(&(k, _)) =
                        changes.updates().iter().find(|&&(k, _)| k >= nnz)
                    {
                        outcomes.push(Err(ServeError::StampOutOfRange { index: k, nnz }));
                        continue;
                    }
                    // change-set batching across timesteps: merge the
                    // following consecutive *valid* stamps into this one
                    // (later updates win per index, so the merged set is
                    // exactly "apply each stamp in order") and pay a
                    // single dirty-block closure + pruned replay for the
                    // whole run. An invalid stamp ends the run and is
                    // rejected on its own next turn.
                    let mut merged = changes;
                    let mut waits = vec![submitted];
                    while self.coalesce_stamps {
                        // like the solve run: only a valid, unexpired
                        // stamp joins the merge
                        match self.queue.front() {
                            Some(f) if !f.expired(Instant::now()) => match &f.request {
                                Request::Stamp { changes }
                                    if !changes
                                        .updates()
                                        .iter()
                                        .any(|&(k, _)| k >= nnz) => {}
                                _ => break,
                            },
                            _ => break,
                        }
                        let Some(Queued {
                            request: Request::Stamp { changes },
                            submitted: t,
                            ..
                        }) = self.queue.pop_front()
                        else {
                            unreachable!("front() just matched a stamp");
                        };
                        merged.extend_from(&changes);
                        waits.push(t);
                    }
                    let start = Instant::now();
                    let est = session.estimate_partial(&merged);
                    let go_partial = est.run_fraction() <= self.partial_threshold;
                    let mut rescued = false;
                    let result = if go_partial {
                        session.refactorize_partial(&merged).or_else(|_first| {
                            // degradation ladder: the pruned replay
                            // faulted (panic, non-finite block, zero
                            // pivot, ...). Retry exactly once as a full
                            // refactorize — its whole-matrix
                            // zero-and-rescatter resets every block, so
                            // poisoned state from the failed attempt
                            // cannot survive into the retry. The
                            // change set is already folded into
                            // `current_values` (partial applies updates
                            // before running), so the retry factors the
                            // stamped matrix.
                            rescued = true;
                            let values = session.current_values().to_vec();
                            session.refactorize(&values)
                        })
                    } else {
                        // closure covers most of the DAG: the full path's
                        // single whole-matrix scatter beats per-block
                        // rescatter — results are bit-identical either way
                        let mut values = session.current_values().to_vec();
                        for &(k, v) in merged.updates() {
                            values[k] = v;
                        }
                        session.refactorize(&values)
                    };
                    if rescued {
                        self.degraded_runs += 1;
                    }
                    let exec_seconds = start.elapsed().as_secs_f64();
                    let batch_size = waits.len();
                    match result {
                        Ok(rep) => {
                            for (run_pos, t) in waits.into_iter().enumerate() {
                                // task counts attributed to the run's
                                // first report only (see ServeReport)
                                let leader = run_pos == 0;
                                outcomes.push(Ok(ServeReport {
                                    kind: RequestKind::Stamp,
                                    queue_seconds: start.duration_since(t).as_secs_f64(),
                                    exec_seconds,
                                    batch_size,
                                    tasks_executed: if leader { rep.tasks_executed } else { 0 },
                                    tasks_skipped: if leader { rep.tasks_skipped } else { 0 },
                                    went_partial: go_partial && !rescued,
                                    solution: None,
                                    trace_id,
                                    refine_iterations: None,
                                    degraded: rescued,
                                }));
                            }
                        }
                        Err(e) => {
                            // the merged execution failed as a unit: every
                            // stamp that rode in it gets the error
                            for _ in waits {
                                outcomes.push(Err(ServeError::Factor(e.clone())));
                            }
                        }
                    }
                }
            }
        }
        outcomes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::FactorPlan;
    use crate::solver::SolveOptions;
    use crate::sparse::gen;
    use std::sync::Arc;

    fn session_for(a: &crate::sparse::Csc) -> SolverSession<'static> {
        SolverSession::from_plan(Arc::new(FactorPlan::build(a, &SolveOptions::ours(1)).unwrap()))
    }

    #[test]
    fn coalesces_consecutive_solves_into_one_batch() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let mut b = Batcher::new(16);
        let rhs: Vec<Vec<f64>> = (0..3)
            .map(|k| (0..64).map(|i| ((i + k) % 5) as f64 - 2.0).collect())
            .collect();
        for r in &rhs {
            b.submit(Request::Solve { rhs: r.clone() }).unwrap();
        }
        let reports: Vec<ServeReport> =
            b.drain(&mut s).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(reports.len(), 3);
        for (rep, r) in reports.iter().zip(&rhs) {
            assert_eq!(rep.kind, RequestKind::Solve);
            assert_eq!(rep.batch_size, 3, "all three solves coalesced");
            assert_eq!(rep.solution.as_ref().unwrap(), &s.solve(r), "batched ≡ individual");
        }
        assert!(b.is_empty());
    }

    #[test]
    fn refactorize_breaks_a_solve_run() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let rhs: Vec<f64> = (0..64).map(|i| (i % 7) as f64).collect();
        let mut b = Batcher::new(16);
        b.submit(Request::Solve { rhs: rhs.clone() }).unwrap();
        b.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        b.submit(Request::Solve { rhs: rhs.clone() }).unwrap();
        b.submit(Request::Solve { rhs }).unwrap();
        let reports: Vec<ServeReport> =
            b.drain(&mut s).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(reports.len(), 4);
        assert_eq!(reports[0].batch_size, 1, "run broken by the refactorize");
        assert_eq!(reports[1].kind, RequestKind::Refactorize);
        assert_eq!(reports[2].batch_size, 2);
        assert_eq!(reports[3].batch_size, 2);
    }

    #[test]
    fn stamp_routing_follows_the_estimate() {
        let a = gen::grid2d_laplacian(10, 10);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let k = a.value_index(57, 57).unwrap();
        // threshold 1.0: everything goes partial
        let mut b = Batcher::new(4).with_partial_threshold(1.0);
        let cs = ChangeSet::from_value_indices([(k, a.values[k] * 2.0)]);
        b.submit(Request::Stamp { changes: cs.clone() }).unwrap();
        let reports = b.drain(&mut s);
        let rep = reports[0].as_ref().unwrap();
        assert!(rep.went_partial);
        assert!(rep.tasks_skipped > 0, "partial path prunes");
        let partial_blocks: Vec<Vec<f64>> = (0..s.plan().structure.blocks.len())
            .map(|id| s.numeric().block_values(id as u32))
            .collect();

        // threshold 0.0: the same stamp goes down the full path —
        // bit-identical factors, nothing pruned
        s.refactorize(&a.values).unwrap();
        let mut b = Batcher::new(4).with_partial_threshold(0.0);
        b.submit(Request::Stamp { changes: cs }).unwrap();
        let reports = b.drain(&mut s);
        let rep = reports[0].as_ref().unwrap();
        assert!(!rep.went_partial);
        assert_eq!(rep.tasks_skipped, 0, "full path executes the whole DAG");
        for (id, want) in partial_blocks.iter().enumerate() {
            assert_eq!(&s.numeric().block_values(id as u32), want, "block {id}");
        }
    }

    #[test]
    fn consecutive_stamps_coalesce_into_one_closure() {
        let a = gen::grid2d_laplacian(10, 10);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let ks = [
            a.value_index(12, 12).unwrap(),
            a.value_index(57, 57).unwrap(),
            a.value_index(57, 57).unwrap(), // same entry restamped: later wins
        ];
        let news = [a.values[ks[0]] * 2.0, a.values[ks[1]] * 3.0, a.values[ks[2]] * 5.0];
        let mut b = Batcher::new(8).with_partial_threshold(1.0);
        for (&k, &v) in ks.iter().zip(&news) {
            b.submit(Request::Stamp { changes: ChangeSet::from_value_indices([(k, v)]) })
                .unwrap();
        }
        let reports: Vec<ServeReport> =
            b.drain(&mut s).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(reports.len(), 3, "one report per stamp");
        assert!(reports.iter().all(|r| r.batch_size == 3), "the run coalesced");
        assert!(reports[0].tasks_executed > 0, "work attributed to the leader");
        assert_eq!(reports[1].tasks_executed, 0, "followers carry no task counts");
        assert_eq!(reports[2].tasks_executed, 0);

        // oracle: stamping one at a time (coalescing off) lands on
        // bit-identical factors
        let mut oracle = session_for(&a);
        oracle.refactorize(&a.values).unwrap();
        let mut ob = Batcher::new(8).with_partial_threshold(1.0).with_stamp_coalescing(false);
        for (&k, &v) in ks.iter().zip(&news) {
            ob.submit(Request::Stamp { changes: ChangeSet::from_value_indices([(k, v)]) })
                .unwrap();
        }
        let one_at_a_time: Vec<ServeReport> =
            ob.drain(&mut oracle).into_iter().map(|r| r.unwrap()).collect();
        assert!(one_at_a_time.iter().all(|r| r.batch_size == 1), "coalescing disabled");
        for id in 0..s.plan().structure.blocks.len() {
            assert_eq!(
                s.numeric().block_values(id as u32),
                oracle.numeric().block_values(id as u32),
                "block {id}: merged stamps diverge from sequential stamps"
            );
        }
        assert_eq!(s.current_values(), oracle.current_values());
    }

    #[test]
    fn solve_breaks_a_stamp_run() {
        let a = gen::grid2d_laplacian(8, 8);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let k = a.value_index(20, 20).unwrap();
        let stamp = |m: f64| Request::Stamp {
            changes: ChangeSet::from_value_indices([(k, a.values[k] * m)]),
        };
        let mut b = Batcher::new(8).with_partial_threshold(1.0);
        b.submit(stamp(2.0)).unwrap();
        b.submit(stamp(3.0)).unwrap();
        b.submit(Request::Solve { rhs: vec![1.0; 64] }).unwrap();
        b.submit(stamp(4.0)).unwrap();
        let reports: Vec<ServeReport> =
            b.drain(&mut s).into_iter().map(|r| r.unwrap()).collect();
        assert_eq!(reports[0].batch_size, 2, "first two stamps coalesce");
        assert_eq!(reports[1].batch_size, 2);
        assert_eq!(reports[2].kind, RequestKind::Solve);
        assert_eq!(reports[3].batch_size, 1, "run broken by the solve");
        // request latency decomposition is reported
        assert!(reports.iter().all(|r| r.queue_seconds >= 0.0 && r.exec_seconds >= 0.0));
    }

    #[test]
    fn queue_bounds_and_input_errors_are_clean() {
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a);
        let mut b = Batcher::new(1);
        let rhs = vec![1.0; 36];
        b.submit(Request::Solve { rhs: rhs.clone() }).unwrap();
        assert!(matches!(
            b.submit(Request::Solve { rhs: rhs.clone() }),
            Err(ServeError::QueueFull { capacity: 1 })
        ));
        // solve before any factorization: clean per-request error
        let outcomes = b.drain(&mut s);
        assert!(matches!(outcomes.as_slice(), [Err(ServeError::NotFactored)]));
        s.refactorize(&a.values).unwrap();
        // wrong-length RHS rejected
        b.submit(Request::Solve { rhs: vec![1.0; 35] }).unwrap();
        let outcomes = b.drain(&mut s);
        assert!(matches!(
            outcomes[..],
            [Err(ServeError::WrongValueCount { got: 35, want: 36 })]
        ));
        // wrong-length value vector rejected
        b.submit(Request::Refactorize { values: vec![1.0; 3] }).unwrap();
        let outcomes = b.drain(&mut s);
        assert!(matches!(outcomes.as_slice(), [Err(ServeError::WrongValueCount { .. })]));
        // out-of-range stamp index rejected without touching the session
        let before = s.current_values().to_vec();
        b.submit(Request::Stamp {
            changes: ChangeSet::from_value_indices([(a.nnz() + 7, 1.0)]),
        })
        .unwrap();
        let outcomes = b.drain(&mut s);
        assert!(matches!(outcomes.as_slice(), [Err(ServeError::StampOutOfRange { .. })]));
        assert_eq!(s.current_values(), &before[..]);
        // failed requests are consumed; the batcher keeps serving
        b.submit(Request::Solve { rhs }).unwrap();
        let outcomes = b.drain(&mut s);
        assert_eq!(outcomes.len(), 1);
        assert!(outcomes[0].as_ref().unwrap().solution.is_some());
    }

    #[test]
    fn low_priority_is_shed_at_the_watermark_high_at_capacity() {
        let mut b = Batcher::new(4);
        let rhs = || Request::Solve { rhs: vec![1.0; 4] };
        assert_eq!(b.low_priority_limit(), 4, "no shedding by default");
        b.set_low_priority_limit(2);
        b.submit_with_priority(rhs(), Priority::Low).unwrap();
        b.submit_with_priority(rhs(), Priority::Low).unwrap();
        // at the watermark: low is shed, high still admitted
        assert!(matches!(
            b.submit_with_priority(rhs(), Priority::Low),
            Err(ServeError::QueueFull { capacity: 4 })
        ));
        b.submit_with_priority(rhs(), Priority::High).unwrap();
        b.submit(rhs()).unwrap(); // plain submit is High
        assert!(matches!(
            b.submit(rhs()),
            Err(ServeError::QueueFull { capacity: 4 })
        ));
        assert_eq!(b.len(), 4);
    }

    #[test]
    fn set_capacity_rebounds_without_dropping_queued_work() {
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let rhs = || Request::Solve { rhs: vec![1.0; 36] };
        let mut b = Batcher::new(2);
        b.submit(rhs()).unwrap();
        b.submit(rhs()).unwrap();
        b.set_capacity(1); // shrink below current length
        assert_eq!(b.len(), 2, "queued requests survive the shrink");
        assert!(matches!(b.submit(rhs()), Err(ServeError::QueueFull { capacity: 1 })));
        assert_eq!(b.drain(&mut s).len(), 2, "both still execute");
        b.submit(rhs()).unwrap();
        assert!(b.submit(rhs()).is_err(), "new bound enforced after drain");
        // growth admits more; the off-state watermark tracks capacity
        b.set_capacity(3);
        assert_eq!(b.low_priority_limit(), 3);
        b.submit(rhs()).unwrap();
        b.submit_with_priority(rhs(), Priority::Low).unwrap();
    }

    #[test]
    fn mixed_batcher_serves_refined_solves_end_to_end() {
        let a = gen::grid2d_laplacian(10, 10);
        let mut s = session_for(&a);
        let mut b = Batcher::new(8).with_precision(Precision::Mixed);
        assert_eq!(b.precision(), Precision::Mixed);
        let rhs: Vec<f64> = (0..100).map(|i| (i % 9) as f64 - 4.0).collect();
        b.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        b.submit(Request::SolveMixed { rhs: rhs.clone() }).unwrap();
        let outcomes = b.drain(&mut s);
        assert_eq!(outcomes.len(), 2);
        assert_eq!(s.precision(), Precision::Mixed, "drain aligned the session");
        assert!(outcomes[0].is_ok(), "refactorize seeds the f32 factors");
        let solve = outcomes[1].as_ref().unwrap();
        assert_eq!(solve.kind, RequestKind::SolveMixed);
        assert_eq!(solve.batch_size, 1, "mixed solves never coalesce");
        assert!(solve.refine_iterations.is_some());
        let x = solve.solution.as_ref().unwrap();
        assert!(
            crate::sparse::residual(&a, x, &rhs) <= 1e-11,
            "refined solution reaches full accuracy"
        );
    }

    #[test]
    fn precision_mismatch_is_rejected_both_ways() {
        let a = gen::grid2d_laplacian(6, 6);
        let rhs = vec![1.0; 36];
        // plain solve on a mixed shard: no f64 factors to solve against
        let mut s = session_for(&a);
        let mut b = Batcher::new(4).with_precision(Precision::Mixed);
        b.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        b.submit(Request::Solve { rhs: rhs.clone() }).unwrap();
        let outcomes = b.drain(&mut s);
        assert!(outcomes[0].is_ok());
        assert!(matches!(
            outcomes[1],
            Err(ServeError::PrecisionMismatch {
                request_needs: Precision::Full,
                session_at: Precision::Mixed,
            })
        ));
        // mixed solve on a full shard: no f32 factors exist
        let mut s = session_for(&a);
        let mut b = Batcher::new(4);
        b.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        b.submit(Request::SolveMixed { rhs }).unwrap();
        let outcomes = b.drain(&mut s);
        assert!(outcomes[0].is_ok());
        assert!(matches!(
            outcomes[1],
            Err(ServeError::PrecisionMismatch {
                request_needs: Precision::Mixed,
                session_at: Precision::Full,
            })
        ));
    }

    #[test]
    fn refinement_divergence_surfaces_as_a_refine_error() {
        // the ill-conditioned bidiagonal from the session tests: every
        // pivot is exactly 1.0 in both precisions, so the only failure
        // mode is the refinement fixed point diverging (κ·ε₃₂ ≫ 1) —
        // which must come back as a per-request ServeError, not a panic
        let n = 30;
        let mut coo = crate::sparse::Coo::new(n, n);
        for i in 0..n {
            coo.push(i, i, 1.0);
            if i + 1 < n {
                coo.push(i, i + 1, -2.1);
            }
        }
        let a = coo.to_csc();
        let mut s = session_for(&a);
        let mut b = Batcher::new(4).with_precision(Precision::Mixed);
        b.submit(Request::Refactorize { values: a.values.clone() }).unwrap();
        b.submit(Request::SolveMixed { rhs: vec![1.0; n] }).unwrap();
        let outcomes = b.drain(&mut s);
        assert!(outcomes[0].is_ok(), "the f32 factorization itself succeeds");
        assert!(matches!(
            outcomes[1],
            Err(ServeError::Refine(crate::session::RefineError::Diverged { .. }))
        ));
    }

    #[test]
    fn bad_request_does_not_poison_its_neighbors() {
        // one malformed solve in the middle of a run: the valid requests
        // around it are all served, and only the bad one gets an error
        let a = gen::grid2d_laplacian(6, 6);
        let mut s = session_for(&a);
        s.refactorize(&a.values).unwrap();
        let good: Vec<f64> = (0..36).map(|i| (i % 5) as f64 - 2.0).collect();
        let mut b = Batcher::new(8);
        b.submit(Request::Solve { rhs: good.clone() }).unwrap();
        b.submit(Request::Solve { rhs: vec![1.0; 35] }).unwrap(); // malformed
        b.submit(Request::Solve { rhs: good.clone() }).unwrap();
        b.submit(Request::Solve { rhs: good.clone() }).unwrap();
        let outcomes = b.drain(&mut s);
        assert_eq!(outcomes.len(), 4);
        assert!(b.is_empty(), "the queue is fully consumed");
        let expected = s.solve(&good);
        assert_eq!(outcomes[0].as_ref().unwrap().batch_size, 1, "run ends at the bad one");
        assert!(matches!(outcomes[1], Err(ServeError::WrongValueCount { .. })));
        for outcome in &outcomes[2..] {
            let rep = outcome.as_ref().unwrap();
            assert_eq!(rep.batch_size, 2, "the two trailing solves re-coalesce");
            assert_eq!(rep.solution.as_ref().unwrap(), &expected);
        }
    }
}
