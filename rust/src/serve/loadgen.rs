//! `loadgen` — closed-loop load generators measuring serving throughput
//! and tail latency: [`run`] drives a single-pattern [`SessionPool`]
//! from K client threads, [`run_multi`] drives a multi-tenant
//! [`Router`] with K clients spread over M distinct sparsity patterns.
//!
//! Each client thread loops: pick a scenario (weighted draw from a
//! per-client deterministic PRNG), check a session out of the pool
//! (blocking when the pool is saturated — the closed loop), execute,
//! check back in. Request latency is measured from *before* the
//! checkout, so pool queueing is part of the tail, exactly as a client
//! would see it. Scenarios:
//!
//! * **full** — full numeric re-factorization to a perturbed value
//!   vector (a Newton step re-stamping everything);
//! * **stamp** — a one-entry diagonal device stamp through the pruned
//!   [`refactorize_partial`] path;
//! * **solve** — a triangular solve against the session's current
//!   factors.
//!
//! The emitted [`LoadgenReport`] serializes to the `BENCH_serve.json`
//! schema consumed by CI (throughput plus p50/p99 per scenario).
//!
//! [`refactorize_partial`]: crate::session::SolverSession::refactorize_partial

use super::batcher::{Request, ServeError, ServeReport};
use super::pool::SessionPool;
use super::router::{Router, RouterConfig, TenantId};
use crate::numeric::Precision;
use crate::session::{ChangeSet, FactorPlan, SolverSession};
use crate::solver::SolveOptions;
use crate::sparse::Csc;
use crate::util::{Prng, Summary};
use std::sync::Arc;
use std::time::Instant;

/// Relative weights of the three request scenarios.
#[derive(Clone, Copy, Debug)]
pub struct ScenarioMix {
    pub full: u32,
    pub stamp: u32,
    pub solve: u32,
}

impl Default for ScenarioMix {
    /// SPICE-flavored default: mostly incremental stamps and solves,
    /// occasional full re-stamps.
    fn default() -> Self {
        Self { full: 1, stamp: 6, solve: 3 }
    }
}

impl ScenarioMix {
    fn total(&self) -> u32 {
        self.full + self.stamp + self.solve
    }

    fn pick(&self, draw: u32) -> Scenario {
        if draw < self.full {
            Scenario::Full
        } else if draw < self.full + self.stamp {
            Scenario::Stamp
        } else {
            Scenario::Solve
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Scenario {
    Full = 0,
    Stamp = 1,
    Solve = 2,
}

const SCENARIO_NAMES: [&str; 3] = ["full", "stamp", "solve"];

/// Load-generator configuration.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Client threads (closed loop: each has one request in flight).
    pub clients: usize,
    /// Requests issued per client.
    pub requests_per_client: usize,
    /// Session pool cap ([`SessionPool::new`] `max_sessions`).
    pub pool_sessions: usize,
    /// Scenario weights.
    pub mix: ScenarioMix,
    /// PRNG seed (per-client streams derive from it deterministically).
    pub seed: u64,
    /// Factor-storage precision for the pooled sessions.
    /// [`Precision::Mixed`] makes the solve scenario run f32-factor
    /// triangular solves with f64 iterative refinement
    /// ([`SolverSession::solve_refined`]); full and stamp scenarios
    /// re-factorize into the f32 shadow storage.
    pub precision: Precision,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        Self {
            clients: 8,
            requests_per_client: 32,
            pool_sessions: 4,
            mix: ScenarioMix::default(),
            seed: 0x5E27E,
            precision: Precision::Full,
        }
    }
}

/// Latency summary of one scenario (or the whole run).
#[derive(Clone, Copy, Debug)]
pub struct LatencyStats {
    pub count: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p99_s: f64,
    pub max_s: f64,
}

impl LatencyStats {
    fn of(latencies: &mut [f64]) -> Self {
        if latencies.is_empty() {
            return Self { count: 0, mean_s: 0.0, p50_s: 0.0, p99_s: 0.0, max_s: 0.0 };
        }
        latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
        let count = latencies.len();
        let mean_s = latencies.iter().sum::<f64>() / count as f64;
        Self {
            count,
            mean_s,
            p50_s: Summary::quantile(latencies, 0.50),
            p99_s: Summary::quantile(latencies, 0.99),
            max_s: latencies[count - 1],
        }
    }
}

/// End-to-end result of one load-generator run.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub clients: usize,
    pub pool_sessions: usize,
    pub total_requests: usize,
    pub wall_seconds: f64,
    /// Completed requests per wall-clock second across all clients.
    pub throughput_rps: f64,
    /// Sessions the pool actually materialized (≤ `pool_sessions`).
    pub sessions_created: usize,
    /// DAG tasks executed / skipped over the whole run (pruning value).
    pub tasks_executed: usize,
    pub tasks_skipped: usize,
    pub overall: LatencyStats,
    /// Per-scenario latency, keyed `full` / `stamp` / `solve`.
    pub per_scenario: Vec<(&'static str, LatencyStats)>,
    /// Factor-storage precision the run was driven at.
    pub precision: Precision,
}

/// JSON-schema name of a precision mode.
fn precision_name(p: Precision) -> &'static str {
    match p {
        Precision::Full => "full",
        Precision::Mixed => "mixed",
    }
}

impl LoadgenReport {
    /// Serialize to the `BENCH_serve.json` schema.
    pub fn to_json(&self, matrix_name: &str, n: usize, nnz: usize) -> String {
        let scenario_rows: Vec<String> = self
            .per_scenario
            .iter()
            .map(|(name, s)| {
                format!(
                    concat!(
                        "      {{\"scenario\": \"{}\", \"count\": {}, ",
                        "\"mean_s\": {:.9}, \"p50_s\": {:.9}, ",
                        "\"p99_s\": {:.9}, \"max_s\": {:.9}}}"
                    ),
                    name, s.count, s.mean_s, s.p50_s, s.p99_s, s.max_s
                )
            })
            .collect();
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"serve\",\n",
                "  \"precision\": \"{}\",\n",
                "  \"matrix\": \"{}\", \"n\": {}, \"nnz\": {},\n",
                "  \"clients\": {}, \"pool_sessions\": {}, ",
                "\"sessions_created\": {},\n",
                "  \"total_requests\": {}, \"wall_seconds\": {:.6}, ",
                "\"throughput_rps\": {:.3},\n",
                "  \"tasks_executed\": {}, \"tasks_skipped\": {},\n",
                "  \"overall\": {{\"p50_s\": {:.9}, \"p99_s\": {:.9}, ",
                "\"mean_s\": {:.9}}},\n",
                "  \"scenarios\": [\n{}\n  ]\n",
                "}}\n"
            ),
            precision_name(self.precision),
            matrix_name,
            n,
            nnz,
            self.clients,
            self.pool_sessions,
            self.sessions_created,
            self.total_requests,
            self.wall_seconds,
            self.throughput_rps,
            self.tasks_executed,
            self.tasks_skipped,
            self.overall.p50_s,
            self.overall.p99_s,
            self.overall.mean_s,
            scenario_rows.join(",\n")
        )
    }
}

/// Ensure `session` holds factors for `a`'s base values (a stamp or
/// solve landing on a virgin session needs a baseline first — that work
/// is billed to the request that needed it, as it would be in a server).
fn ensure_factored(session: &mut SolverSession<'_>, a: &Csc) -> (usize, usize) {
    if session.is_factored() {
        return (0, 0);
    }
    let rep = session.refactorize(&a.values).expect("baseline refactorize");
    (rep.tasks_executed, rep.tasks_skipped)
}

/// Drive `pool` with `cfg.clients` closed-loop client threads over the
/// scenario mix. `plan` must have been built for `a`'s pattern.
pub fn run(a: &Csc, plan: Arc<FactorPlan>, cfg: &LoadgenConfig) -> LoadgenReport {
    assert!(plan.matches(a), "loadgen plan must match the driven matrix");
    assert!(cfg.clients > 0 && cfg.requests_per_client > 0, "empty load");
    assert!(cfg.mix.total() > 0, "scenario mix must have positive weight");
    let pool = SessionPool::new(plan, cfg.pool_sessions);
    let n = a.n_rows();
    let mix_total = cfg.mix.total();

    let t0 = Instant::now();
    // (scenario, latency, tasks_executed, tasks_skipped) per request
    let mut samples: Vec<(Scenario, f64, usize, usize)> =
        Vec::with_capacity(cfg.clients * cfg.requests_per_client);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|client| {
                let pool = &pool;
                scope.spawn(move || {
                    let mut rng =
                        Prng::new(cfg.seed ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    let mut out = Vec::with_capacity(cfg.requests_per_client);
                    for _ in 0..cfg.requests_per_client {
                        let scenario = cfg.mix.pick(rng.below(mix_total as usize) as u32);
                        let start = Instant::now();
                        let mut session = pool.checkout();
                        // pooled sessions start at Precision::Full; a mixed
                        // run converts each on first touch (the flip drops
                        // the factors, so ensure_factored re-seeds below)
                        if session.precision() != cfg.precision {
                            session.set_precision(cfg.precision);
                        }
                        let (mut executed, mut skipped) = (0usize, 0usize);
                        match scenario {
                            Scenario::Full => {
                                let values: Vec<f64> = a
                                    .values
                                    .iter()
                                    .map(|v| v * (1.0 + 0.02 * rng.signed_unit()))
                                    .collect();
                                let rep =
                                    session.refactorize(&values).expect("full refactorize");
                                executed = rep.tasks_executed;
                                skipped = rep.tasks_skipped;
                            }
                            Scenario::Stamp => {
                                let (e0, s0) = ensure_factored(&mut session, a);
                                let d = rng.below(n);
                                let k = a
                                    .value_index(d, d)
                                    .expect("generator matrices have full diagonals");
                                // multiplier stays within [1.015, 1.03):
                                // never 1.0, so the stamp is a real change
                                let nv = session.current_values()[k]
                                    * (1.0 + 0.03 * (0.5 + 0.5 * rng.f64()));
                                let cs = ChangeSet::from_value_indices([(k, nv)]);
                                let rep = session
                                    .refactorize_partial(&cs)
                                    .expect("partial refactorize");
                                executed = e0 + rep.tasks_executed;
                                skipped = s0 + rep.tasks_skipped;
                            }
                            Scenario::Solve => {
                                let (e0, s0) = ensure_factored(&mut session, a);
                                let b: Vec<f64> =
                                    (0..n).map(|_| rng.signed_unit()).collect();
                                if cfg.precision == Precision::Mixed {
                                    let refined = session
                                        .solve_refined(&b)
                                        .expect("refinement converges on suite matrices");
                                    std::hint::black_box(&refined.x);
                                } else {
                                    let x = session.solve(&b);
                                    std::hint::black_box(&x);
                                }
                                executed = e0;
                                skipped = s0;
                            }
                        }
                        // checkin happens inside the latency window: the
                        // request is not served until its session is
                        // reusable by the next client
                        drop(session);
                        out.push((scenario, start.elapsed().as_secs_f64(), executed, skipped));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            samples.extend(h.join().expect("client thread panicked"));
        }
    });
    let wall_seconds = t0.elapsed().as_secs_f64();

    let total_requests = samples.len();
    let mut overall: Vec<f64> = Vec::with_capacity(total_requests);
    let mut per: [Vec<f64>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    let (mut tasks_executed, mut tasks_skipped) = (0usize, 0usize);
    for &(scenario, latency, executed, skipped) in &samples {
        overall.push(latency);
        per[scenario as usize].push(latency);
        tasks_executed += executed;
        tasks_skipped += skipped;
    }
    let per_scenario = SCENARIO_NAMES
        .iter()
        .zip(per.iter_mut())
        .map(|(&name, lat)| (name, LatencyStats::of(lat)))
        .collect();
    LoadgenReport {
        clients: cfg.clients,
        pool_sessions: cfg.pool_sessions,
        total_requests,
        wall_seconds,
        throughput_rps: total_requests as f64 / wall_seconds.max(1e-12),
        sessions_created: pool.stats().created,
        tasks_executed,
        tasks_skipped,
        overall: LatencyStats::of(&mut overall),
        per_scenario,
        precision: cfg.precision,
    }
}

/// Multi-tenant load-generator configuration ([`run_multi`]).
#[derive(Clone, Debug)]
pub struct MultiTenantConfig {
    /// Client threads, spread round-robin over the tenants (client `c`
    /// talks to tenant `c % M`).
    pub clients: usize,
    /// Requests issued per client.
    pub requests_per_client: usize,
    /// Requests each client submits before draining its tenant's shard —
    /// the knob that makes solve coalescing and change-set batching
    /// visible under load.
    pub burst: usize,
    /// Scenario weights (each client's **first** request is always a
    /// full refactorize so its shard's factors are seeded).
    pub mix: ScenarioMix,
    /// PRNG seed (per-client streams derive deterministically).
    pub seed: u64,
    /// Router sizing. `max_shards` is clamped up to the tenant count so
    /// no tenant is evicted mid-run.
    pub router: RouterConfig,
    /// When set, run an [`crate::obs::Autoscaler`] with this policy on a
    /// background thread for the duration of the load: session pools and
    /// queue bounds resize live while the clients hammer the router.
    /// Clients submit at [`super::batcher::Priority::High`], so shedding
    /// never rejects the closed-loop load itself.
    pub autoscale: Option<crate::obs::SloPolicy>,
}

impl Default for MultiTenantConfig {
    fn default() -> Self {
        Self {
            clients: 8,
            requests_per_client: 32,
            burst: 4,
            mix: ScenarioMix::default(),
            seed: 0x3E2A17,
            router: RouterConfig::default(),
            autoscale: None,
        }
    }
}

/// One tenant's share of a [`run_multi`] report.
#[derive(Clone, Debug)]
pub struct TenantBench {
    pub name: String,
    pub n: usize,
    pub nnz: usize,
    /// Clients assigned to this tenant.
    pub clients: usize,
    /// Requests that completed successfully / returned an error.
    pub completed: usize,
    pub errors: usize,
    /// Submissions bounced by admission control
    /// ([`ServeError::ShardFull`]); each was retried after a drain.
    pub rejections: usize,
    /// Completed requests per wall-clock second for this tenant alone.
    pub throughput_rps: f64,
    /// Server-side latency (queue wait + execution) of this tenant's
    /// completed requests.
    pub latency: LatencyStats,
    /// DAG tasks executed / skipped on this tenant's behalf.
    pub tasks_executed: usize,
    pub tasks_skipped: usize,
}

/// End-to-end result of one multi-tenant load-generator run.
#[derive(Clone, Debug)]
pub struct MultiTenantReport {
    pub clients: usize,
    pub tenants: usize,
    pub total_requests: usize,
    pub wall_seconds: f64,
    /// Completed requests per wall-clock second across all tenants.
    pub throughput_rps: f64,
    /// Router counters at the end of the run.
    pub router: crate::serve::RouterStats,
    /// Latency over every completed request of every tenant.
    pub overall: LatencyStats,
    pub per_tenant: Vec<TenantBench>,
    /// Factor-storage precision every shard served at
    /// ([`RouterConfig::precision`]).
    pub precision: Precision,
}

impl MultiTenantReport {
    /// Serialize to the `BENCH_serve.json` multi-tenant schema.
    pub fn to_json(&self) -> String {
        let tenant_rows: Vec<String> = self
            .per_tenant
            .iter()
            .map(|t| {
                format!(
                    concat!(
                        "      {{\"tenant\": \"{}\", \"n\": {}, \"nnz\": {}, ",
                        "\"clients\": {}, \"completed\": {}, \"errors\": {}, ",
                        "\"rejections\": {},\n",
                        "       \"throughput_rps\": {:.3}, ",
                        "\"p50_s\": {:.9}, \"p99_s\": {:.9}, ",
                        "\"mean_s\": {:.9}, \"max_s\": {:.9},\n",
                        "       \"tasks_executed\": {}, \"tasks_skipped\": {}}}"
                    ),
                    t.name,
                    t.n,
                    t.nnz,
                    t.clients,
                    t.completed,
                    t.errors,
                    t.rejections,
                    t.throughput_rps,
                    t.latency.p50_s,
                    t.latency.p99_s,
                    t.latency.mean_s,
                    t.latency.max_s,
                    t.tasks_executed,
                    t.tasks_skipped
                )
            })
            .collect();
        format!(
            concat!(
                "{{\n",
                "  \"bench\": \"serve-multi\",\n",
                "  \"precision\": \"{}\",\n",
                "  \"clients\": {}, \"tenants\": {}, ",
                "\"total_requests\": {}, \"wall_seconds\": {:.6}, ",
                "\"throughput_rps\": {:.3},\n",
                "  \"router\": {{\"spin_ups\": {}, \"evictions\": {}, ",
                "\"revivals\": {}, \"plans_warmed\": {}, ",
                "\"cache_hits\": {}, \"cache_misses\": {}}},\n",
                "  \"overall\": {{\"p50_s\": {:.9}, \"p99_s\": {:.9}, ",
                "\"mean_s\": {:.9}}},\n",
                "  \"per_tenant\": [\n{}\n  ]\n",
                "}}\n"
            ),
            precision_name(self.precision),
            self.clients,
            self.tenants,
            self.total_requests,
            self.wall_seconds,
            self.throughput_rps,
            self.router.spin_ups,
            self.router.evictions,
            self.router.revivals,
            self.router.plans_warmed,
            self.router.cache_hits,
            self.router.cache_misses,
            self.overall.p50_s,
            self.overall.p99_s,
            self.overall.mean_s,
            tenant_rows.join(",\n")
        )
    }
}

/// Drive a multi-tenant [`Router`] with `cfg.clients` closed-loop client
/// threads spread over `tenants` (name + matrix, one per distinct
/// sparsity pattern). Each client submits bursts to its own tenant and
/// drains that tenant's shard — so shards of different tenants execute
/// concurrently, exactly the contention pattern a multi-matrix serving
/// process sees. Latency is the server-side queue + execution time per
/// request; per-tenant throughput counts only that tenant's completed
/// requests.
pub fn run_multi(
    tenants: &[(String, Csc)],
    opts: &SolveOptions,
    cfg: &MultiTenantConfig,
) -> MultiTenantReport {
    assert!(!tenants.is_empty(), "run_multi needs at least one tenant");
    assert!(cfg.clients > 0 && cfg.requests_per_client > 0, "empty load");
    assert!(cfg.mix.total() > 0, "scenario mix must have positive weight");
    let m = tenants.len();
    let mut router_cfg = cfg.router.clone();
    router_cfg.max_shards = router_cfg.max_shards.max(m);
    router_cfg.plan_cache_capacity = router_cfg.plan_cache_capacity.max(router_cfg.max_shards);
    let router = Arc::new(Router::new(opts.clone(), router_cfg));
    let ids: Vec<TenantId> = tenants
        .iter()
        .map(|(name, a)| {
            router.admit(a).unwrap_or_else(|e| panic!("admitting tenant {name}: {e}"))
        })
        .collect();
    let autoscaler = cfg.autoscale.map(|policy| {
        Arc::new(crate::obs::Autoscaler::new(router.clone(), policy))
            .spawn(std::time::Duration::from_millis(20))
    });

    let t0 = Instant::now();
    // (tenant index, outcome) per completed-or-errored request
    let mut samples: Vec<(usize, Result<ServeReport, ServeError>)> =
        Vec::with_capacity(cfg.clients * cfg.requests_per_client);
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..cfg.clients)
            .map(|client| {
                let (router, ids) = (&router, &ids);
                scope.spawn(move || {
                    let t_idx = client % m;
                    let (_, a) = &tenants[t_idx];
                    let id = ids[t_idx];
                    let n = a.n_rows();
                    let mut rng = Prng::new(
                        cfg.seed ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    let mut out = Vec::with_capacity(cfg.requests_per_client);
                    let mut issued = 0;
                    while issued < cfg.requests_per_client {
                        let burst = cfg.burst.clamp(1, cfg.requests_per_client - issued);
                        for _ in 0..burst {
                            let request = if issued == 0 {
                                // seed the shard's factors before any
                                // stamp/solve can land
                                Request::Refactorize { values: a.values.clone() }
                            } else {
                                match cfg.mix.pick(rng.below(cfg.mix.total() as usize) as u32)
                                {
                                    Scenario::Full => Request::Refactorize {
                                        values: a
                                            .values
                                            .iter()
                                            .map(|v| v * (1.0 + 0.02 * rng.signed_unit()))
                                            .collect(),
                                    },
                                    Scenario::Stamp => {
                                        let d = rng.below(n);
                                        let k = a
                                            .value_index(d, d)
                                            .expect("generator matrices have full diagonals");
                                        let nv = a.values[k]
                                            * (1.0 + 0.03 * (0.5 + 0.5 * rng.f64()));
                                        Request::Stamp {
                                            changes: ChangeSet::from_value_indices([(k, nv)]),
                                        }
                                    }
                                    Scenario::Solve => {
                                        // route to the request kind the shard's
                                        // precision accepts — a mismatch would be
                                        // a hard ServeError::PrecisionMismatch
                                        let rhs =
                                            (0..n).map(|_| rng.signed_unit()).collect();
                                        if cfg.router.precision == Precision::Mixed {
                                            Request::SolveMixed { rhs }
                                        } else {
                                            Request::Solve { rhs }
                                        }
                                    }
                                }
                            };
                            // closed loop with backpressure: a ShardFull
                            // rejection drains our own shard, backs off
                            // (bounded exponential — 50µs doubling to a
                            // 3.2ms ceiling so a storm of rejected
                            // clients decorrelates instead of
                            // thundering back in lockstep), then
                            // resubmits
                            let mut backoff_us: u64 = 50;
                            loop {
                                match router.submit(id, request.clone()) {
                                    Ok(()) => break,
                                    Err(ServeError::ShardFull { .. }) => {
                                        let drained = router
                                            .drain_tenant(id)
                                            .expect("admitted tenant stays live");
                                        out.extend(
                                            drained.into_iter().map(|o| (t_idx, o)),
                                        );
                                        std::thread::sleep(
                                            std::time::Duration::from_micros(backoff_us),
                                        );
                                        backoff_us = (backoff_us * 2).min(3200);
                                    }
                                    Err(e) => panic!("unexpected submit failure: {e}"),
                                }
                            }
                            issued += 1;
                        }
                        let drained =
                            router.drain_tenant(id).expect("admitted tenant stays live");
                        out.extend(drained.into_iter().map(|o| (t_idx, o)));
                    }
                    out
                })
            })
            .collect();
        for handle in handles {
            samples.extend(handle.join().expect("client thread panicked"));
        }
    });
    let wall_seconds = t0.elapsed().as_secs_f64();
    if let Some(handle) = autoscaler {
        handle.stop(); // joined: tenant stats below are post-final-tick
    }

    let mut completed = vec![0usize; m];
    let mut errors = vec![0usize; m];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); m];
    let mut overall: Vec<f64> = Vec::with_capacity(samples.len());
    for (t_idx, outcome) in &samples {
        match outcome {
            Ok(rep) => {
                completed[*t_idx] += 1;
                let latency = rep.queue_seconds + rep.exec_seconds;
                latencies[*t_idx].push(latency);
                overall.push(latency);
            }
            Err(_) => errors[*t_idx] += 1,
        }
    }
    let per_tenant: Vec<TenantBench> = tenants
        .iter()
        .enumerate()
        .map(|(i, (name, a))| {
            let stats = router.tenant_stats(ids[i]).expect("admitted tenant stays live");
            TenantBench {
                name: name.clone(),
                n: a.n_rows(),
                nnz: a.nnz(),
                clients: (cfg.clients + m - 1 - i) / m,
                completed: completed[i],
                errors: errors[i],
                rejections: stats.rejected,
                throughput_rps: completed[i] as f64 / wall_seconds.max(1e-12),
                latency: LatencyStats::of(&mut latencies[i]),
                tasks_executed: stats.tasks_executed,
                tasks_skipped: stats.tasks_skipped,
            }
        })
        .collect();
    let total_requests = samples.len();
    MultiTenantReport {
        clients: cfg.clients,
        tenants: m,
        total_requests,
        wall_seconds,
        throughput_rps: completed.iter().sum::<usize>() as f64 / wall_seconds.max(1e-12),
        router: router.stats(),
        overall: LatencyStats::of(&mut overall),
        per_tenant,
        precision: cfg.router.precision,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::SolveOptions;
    use crate::sparse::gen;

    #[test]
    fn loadgen_completes_every_request_and_reports_latencies() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let cfg = LoadgenConfig {
            clients: 4,
            requests_per_client: 6,
            pool_sessions: 2,
            ..Default::default()
        };
        let report = run(&a, plan, &cfg);
        assert_eq!(report.total_requests, 24);
        assert!(report.throughput_rps > 0.0);
        assert!(report.sessions_created <= 2, "growth bounded by the pool cap");
        assert_eq!(report.overall.count, 24);
        let counted: usize = report.per_scenario.iter().map(|(_, s)| s.count).sum();
        assert_eq!(counted, 24, "every request lands in exactly one scenario bucket");
        assert!(report.overall.p99_s >= report.overall.p50_s);
        assert!(report.overall.max_s >= report.overall.p99_s);
        assert!(report.tasks_executed > 0);
        let json = report.to_json("bbd-200", a.n_rows(), a.nnz());
        assert!(json.contains("\"bench\": \"serve\""));
        assert!(json.contains("\"scenario\": \"stamp\""));
    }

    #[test]
    fn multi_tenant_loadgen_serves_every_tenant_and_reports_per_tenant() {
        let tenants = vec![
            ("bbd-200".to_string(), gen::circuit_bbd(gen::CircuitParams {
                n: 200,
                ..Default::default()
            })),
            ("grid-9x9".to_string(), gen::grid2d_laplacian(9, 9)),
        ];
        let cfg = MultiTenantConfig {
            clients: 4,
            requests_per_client: 6,
            burst: 3,
            ..Default::default()
        };
        let report = run_multi(&tenants, &SolveOptions::ours(1), &cfg);
        assert_eq!(report.tenants, 2);
        assert_eq!(report.total_requests, 24, "every request is accounted for");
        assert_eq!(report.router.spin_ups, 2);
        assert_eq!(report.router.evictions, 0, "no tenant evicted mid-run");
        let completed: usize = report.per_tenant.iter().map(|t| t.completed).sum();
        let errors: usize = report.per_tenant.iter().map(|t| t.errors).sum();
        assert_eq!(completed + errors, 24);
        assert_eq!(errors, 0, "seeded shards never see NotFactored");
        for t in &report.per_tenant {
            assert_eq!(t.clients, 2);
            assert!(t.completed > 0, "tenant {} starved", t.name);
            assert!(t.throughput_rps > 0.0);
            assert!(t.latency.p99_s >= t.latency.p50_s);
            assert!(t.tasks_executed > 0, "tenant {} never factorized", t.name);
        }
        let json = report.to_json();
        assert!(json.contains("\"bench\": \"serve-multi\""));
        assert!(json.contains("\"tenant\": \"bbd-200\""));
        assert!(json.contains("\"tenant\": \"grid-9x9\""));
        assert!(json.contains("\"per_tenant\""));
    }

    #[test]
    fn mixed_precision_loadgen_runs_refined_solves() {
        let a = gen::circuit_bbd(gen::CircuitParams { n: 200, ..Default::default() });
        let plan = Arc::new(FactorPlan::build(&a, &SolveOptions::ours(1)).unwrap());
        let cfg = LoadgenConfig {
            clients: 2,
            requests_per_client: 6,
            pool_sessions: 1,
            precision: Precision::Mixed,
            ..Default::default()
        };
        let report = run(&a, plan, &cfg);
        assert_eq!(report.total_requests, 12);
        assert_eq!(report.precision, Precision::Mixed);
        let json = report.to_json("bbd-200", a.n_rows(), a.nnz());
        assert!(json.contains("\"precision\": \"mixed\""));
    }

    #[test]
    fn multi_tenant_loadgen_routes_mixed_solves() {
        let tenants = vec![
            ("bbd-200".to_string(), gen::circuit_bbd(gen::CircuitParams {
                n: 200,
                ..Default::default()
            })),
            ("grid-9x9".to_string(), gen::grid2d_laplacian(9, 9)),
        ];
        let cfg = MultiTenantConfig {
            clients: 2,
            requests_per_client: 8,
            burst: 2,
            mix: ScenarioMix { full: 1, stamp: 1, solve: 6 },
            router: RouterConfig { precision: Precision::Mixed, ..RouterConfig::default() },
            ..Default::default()
        };
        let report = run_multi(&tenants, &SolveOptions::ours(1), &cfg);
        assert_eq!(report.total_requests, 16);
        let errors: usize = report.per_tenant.iter().map(|t| t.errors).sum();
        assert_eq!(errors, 0, "mixed solves converge and match the shard precision");
        assert!(report.to_json().contains("\"precision\": \"mixed\""));
    }

    #[test]
    fn same_seed_same_scenario_sequence() {
        let mix = ScenarioMix::default();
        let draws: Vec<Scenario> = {
            let mut rng = Prng::new(42);
            (0..50).map(|_| mix.pick(rng.below(mix.total() as usize) as u32)).collect()
        };
        let again: Vec<Scenario> = {
            let mut rng = Prng::new(42);
            (0..50).map(|_| mix.pick(rng.below(mix.total() as usize) as u32)).collect()
        };
        assert_eq!(draws, again);
        // all three scenarios appear under the default weights
        for s in [Scenario::Full, Scenario::Stamp, Scenario::Solve] {
            assert!(draws.contains(&s), "{s:?} never drawn");
        }
    }
}
