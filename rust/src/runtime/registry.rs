//! Artifact registry: discovers `artifacts/{op}_{size}.hlo.txt`, compiles
//! each once on the PJRT CPU client, and dispatches executions.

use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Tile sizes the AOT pipeline exports (must match `python/compile/aot.py`).
pub const TILE_SIZES: &[usize] = &[32, 64, 128, 256];

/// Artifact operation kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    /// In-place LU of a square tile.
    Getrf,
    /// `L⁻¹ B` with unit-lower L from `{L\U}`.
    TrsmLower,
    /// `B U⁻¹` with upper U from `{L\U}`.
    TrsmUpper,
    /// `C − A·B`.
    Gemm,
}

impl Op {
    pub fn file_stem(self) -> &'static str {
        match self {
            Op::Getrf => "getrf",
            Op::TrsmLower => "trsm_l",
            Op::TrsmUpper => "trsm_u",
            Op::Gemm => "gemm",
        }
    }

    pub const ALL: [Op; 4] = [Op::Getrf, Op::TrsmLower, Op::TrsmUpper, Op::Gemm];
}

/// Compiled executables keyed by (op, tile size).
///
/// NOT `Send`/`Sync` (PJRT handles are thread-affine in the `xla` crate) —
/// [`super::PjrtDense`] hosts one registry on a dedicated service thread.
pub struct ArtifactRegistry {
    _client: xla::PjRtClient,
    exes: HashMap<(Op, usize), xla::PjRtLoadedExecutable>,
    sizes: Vec<usize>,
    executions: AtomicUsize,
}

impl ArtifactRegistry {
    /// Load and compile every artifact found in `dir`. Errors if the
    /// directory exists but holds no recognizable artifacts.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref();
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let mut exes = HashMap::new();
        let mut sizes: Vec<usize> = Vec::new();
        for &size in TILE_SIZES {
            let mut found_all = true;
            for op in Op::ALL {
                let path = dir.join(format!("{}_{}.hlo.txt", op.file_stem(), size));
                if !path.exists() {
                    found_all = false;
                    continue;
                }
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("artifact path not utf-8")?,
                )
                .with_context(|| format!("parse {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .with_context(|| format!("compile {}", path.display()))?;
                exes.insert((op, size), exe);
            }
            if found_all {
                sizes.push(size);
            }
        }
        if exes.is_empty() {
            bail!(
                "no artifacts found in {} — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(Self { _client: client, exes, sizes, executions: AtomicUsize::new(0) })
    }

    /// Number of compiled executables.
    pub fn len(&self) -> usize {
        self.exes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.exes.is_empty()
    }

    /// Total executions dispatched.
    pub fn executions(&self) -> usize {
        self.executions.load(Ordering::Relaxed)
    }

    /// Smallest complete tile size ≥ `n`.
    pub fn tile_for(&self, n: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| s >= n)
    }

    fn run(&self, op: Op, size: usize, args: &[xla::Literal]) -> Result<Vec<f64>> {
        let exe = self
            .exes
            .get(&(op, size))
            .with_context(|| format!("artifact {:?}@{size} not loaded", op))?;
        self.executions.fetch_add(1, Ordering::Relaxed);
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True → 1-tuple
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }

    /// Column-major square-matrix helpers. JAX tensors are row-major; the
    /// AOT graphs take/return **transposed** matrices so the rust side can
    /// pass col-major buffers verbatim (a transpose in index space only —
    /// see `python/compile/model.py`).
    fn lit(size: usize, data: &[f64]) -> Result<xla::Literal> {
        debug_assert_eq!(data.len(), size * size);
        Ok(xla::Literal::vec1(data).reshape(&[size as i64, size as i64])?)
    }

    pub fn run1(&self, op: Op, size: usize, a: &[f64]) -> Result<Vec<f64>> {
        self.run(op, size, &[Self::lit(size, a)?])
    }

    pub fn run2(&self, op: Op, size: usize, a: &[f64], b: &[f64]) -> Result<Vec<f64>> {
        self.run(op, size, &[Self::lit(size, a)?, Self::lit(size, b)?])
    }

    pub fn run3(
        &self,
        op: Op,
        size: usize,
        a: &[f64],
        b: &[f64],
        c: &[f64],
    ) -> Result<Vec<f64>> {
        self.run(
            op,
            size,
            &[Self::lit(size, a)?, Self::lit(size, b)?, Self::lit(size, c)?],
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn op_file_stems_unique() {
        let stems: std::collections::HashSet<_> =
            Op::ALL.iter().map(|o| o.file_stem()).collect();
        assert_eq!(stems.len(), 4);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(ArtifactRegistry::load("/nonexistent/path").is_err());
    }
}
