//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` (L1 Pallas kernels inside an L2 jax graph,
//! lowered once at build time) and exposes them as a
//! [`DenseBackend`](crate::numeric::factor::DenseBackend) for the numeric
//! engine's dense path.
//!
//! Interchange format is **HLO text** (not serialized protos): jax ≥ 0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see `/opt/xla-example/README.md`).
//!
//! ## Threading
//!
//! The `xla` crate's PJRT handles are thread-affine (`Rc` internals), so
//! [`PjrtDense`] hosts the compiled executables on a dedicated **service
//! thread** — worker threads submit requests over a channel and block on a
//! per-call reply channel. This mirrors a real deployment where one GPU
//! context serves kernel launches from a scheduler. Padding rules:
//! identity padding keeps LU/TRSM exact, zero padding keeps GEMM exact, so
//! padded execution matches unpadded math to fp-reassociation error.

pub mod registry;

pub use registry::{ArtifactRegistry, Op, TILE_SIZES};

use crate::numeric::factor::DenseBackend;
use crate::numeric::kernels::KernelError;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;

enum Request {
    Run {
        op: Op,
        size: usize,
        args: Vec<Vec<f64>>,
        reply: Sender<anyhow::Result<Vec<f64>>>,
    },
    Shutdown,
}

/// Dense backend executing AOT artifacts on the PJRT CPU client, hosted on
/// a service thread. `Send + Sync`; cheap to share across workers.
///
/// Submission is lock-free on the caller side: the mpsc sender is `Sync`
/// (Rust ≥ 1.72), so a pool of executor workers dispatching dense ops
/// sends directly on the shared channel instead of convoying on the old
/// `Mutex<Sender>` — they serialize only where the hardware does, at the
/// service thread itself.
pub struct PjrtDense {
    tx: Sender<Request>,
    sizes: Vec<usize>,
    num_artifacts: usize,
    executions: Arc<AtomicUsize>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl PjrtDense {
    /// Spawn the service thread and load all artifacts from `dir`.
    pub fn load(dir: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let (tx, rx) = channel::<Request>();
        let (boot_tx, boot_rx) = channel::<anyhow::Result<(Vec<usize>, usize)>>();
        let executions = Arc::new(AtomicUsize::new(0));
        let execs = executions.clone();
        let handle = std::thread::Builder::new()
            .name("pjrt-service".into())
            .spawn(move || {
                let reg = match ArtifactRegistry::load(&dir) {
                    Ok(r) => {
                        let sizes: Vec<usize> =
                            TILE_SIZES.iter().copied().filter(|&s| reg_has(&r, s)).collect();
                        let _ = boot_tx.send(Ok((sizes, r.len())));
                        r
                    }
                    Err(e) => {
                        let _ = boot_tx.send(Err(e));
                        return;
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        Request::Run { op, size, args, reply } => {
                            execs.fetch_add(1, Ordering::Relaxed);
                            let res = match args.len() {
                                1 => reg.run1(op, size, &args[0]),
                                2 => reg.run2(op, size, &args[0], &args[1]),
                                3 => reg.run3(op, size, &args[0], &args[1], &args[2]),
                                n => Err(anyhow::anyhow!("bad arity {n}")),
                            };
                            let _ = reply.send(res);
                        }
                        Request::Shutdown => break,
                    }
                }
            })?;
        let (sizes, num_artifacts) = boot_rx.recv()??;
        Ok(Self { tx, sizes, num_artifacts, executions, handle: Some(handle) })
    }

    /// The tile size used for a requested dimension.
    pub fn tile_for(&self, n: usize) -> Option<usize> {
        self.sizes.iter().copied().find(|&s| s >= n)
    }

    /// Largest supported tile.
    pub fn max_tile(&self) -> usize {
        self.sizes.last().copied().unwrap_or(0)
    }

    /// Number of loaded executables.
    pub fn num_artifacts(&self) -> usize {
        self.num_artifacts
    }

    /// Executions dispatched so far.
    pub fn executions(&self) -> usize {
        self.executions.load(Ordering::Relaxed)
    }

    fn call(&self, op: Op, size: usize, args: Vec<Vec<f64>>) -> anyhow::Result<Vec<f64>> {
        let (reply_tx, reply_rx) = channel();
        // `mpsc::Sender` is `Sync` (Rust >= 1.72): concurrent submitters
        // enqueue directly on the channel's lock-free queue — the old
        // `Mutex<Sender>` convoy point is gone entirely
        self.tx
            .send(Request::Run { op, size, args, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("PJRT service thread gone"))?;
        reply_rx.recv()?
    }

    fn pad_square(src: &[f64], n: usize, t: usize, identity: bool) -> Vec<f64> {
        let mut out = vec![0.0; t * t];
        for c in 0..n {
            out[c * t..c * t + n].copy_from_slice(&src[c * n..(c + 1) * n]);
        }
        if identity {
            for d in n..t {
                out[d * t + d] = 1.0;
            }
        }
        out
    }

    fn pad_rect(src: &[f64], m: usize, k: usize, tm: usize, tk: usize) -> Vec<f64> {
        let mut out = vec![0.0; tm * tk];
        for c in 0..k {
            out[c * tm..c * tm + m].copy_from_slice(&src[c * m..(c + 1) * m]);
        }
        out
    }

    fn unpad_rect(dst: &mut [f64], src: &[f64], m: usize, k: usize, tm: usize) {
        for c in 0..k {
            dst[c * m..(c + 1) * m].copy_from_slice(&src[c * tm..c * tm + m]);
        }
    }
}

fn reg_has(reg: &ArtifactRegistry, size: usize) -> bool {
    reg.tile_for(size) == Some(size)
}

impl Drop for PjrtDense {
    fn drop(&mut self) {
        let _ = self.tx.send(Request::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl DenseBackend for PjrtDense {
    fn getrf(&self, a: &mut [f64], n: usize) -> Result<(), KernelError> {
        let t = self.tile_for(n).expect("no tile large enough for GETRF");
        // identity padding: trailing pivots are 1, factorization unchanged
        let padded = Self::pad_square(a, n, t, true);
        let out = self
            .call(Op::Getrf, t, vec![padded])
            .expect("PJRT GETRF execution failed");
        for d in 0..n {
            let p = out[d * t + d];
            if p.abs() < crate::numeric::kernels::PIVOT_FLOOR {
                return Err(KernelError::ZeroPivot { block: (0, 0), local_col: d, value: p });
            }
        }
        Self::unpad_rect(a, &out, n, n, t);
        Ok(())
    }

    fn trsm_lower(&self, lu: &[f64], m: usize, b: &mut [f64], k: usize) {
        let t = self.tile_for(m.max(k)).expect("no tile for TRSM-L");
        let lu_p = Self::pad_square(lu, m, t, true);
        let b_p = Self::pad_rect(b, m, k, t, t);
        let out = self
            .call(Op::TrsmLower, t, vec![lu_p, b_p])
            .expect("PJRT TRSM-L execution failed");
        Self::unpad_rect(b, &out, m, k, t);
    }

    fn trsm_upper(&self, lu: &[f64], k: usize, b: &mut [f64], m: usize) {
        let t = self.tile_for(m.max(k)).expect("no tile for TRSM-U");
        let lu_p = Self::pad_square(lu, k, t, true);
        let b_p = Self::pad_rect(b, m, k, t, t);
        let out = self
            .call(Op::TrsmUpper, t, vec![lu_p, b_p])
            .expect("PJRT TRSM-U execution failed");
        Self::unpad_rect(b, &out, m, k, t);
    }

    fn gemm(&self, c: &mut [f64], a: &[f64], b: &[f64], m: usize, k: usize, n: usize) {
        let t = self.tile_for(m.max(k).max(n)).expect("no tile for GEMM");
        let a_p = Self::pad_rect(a, m, k, t, t);
        let b_p = Self::pad_rect(b, k, n, t, t);
        let c_p = Self::pad_rect(c, m, n, t, t);
        let out = self
            .call(Op::Gemm, t, vec![c_p, a_p, b_p])
            .expect("PJRT GEMM execution failed");
        Self::unpad_rect(c, &out, m, n, t);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn padding_round_trip() {
        let src = vec![1.0, 2.0, 3.0, 4.0]; // 2x2 col-major
        let p = PjrtDense::pad_square(&src, 2, 4, true);
        assert_eq!(p[0], 1.0);
        assert_eq!(p[1], 2.0);
        assert_eq!(p[4], 3.0);
        assert_eq!(p[5], 4.0);
        assert_eq!(p[10], 1.0); // identity diag
        assert_eq!(p[15], 1.0);
        let mut back = vec![0.0; 4];
        PjrtDense::unpad_rect(&mut back, &p, 2, 2, 4);
        assert_eq!(back, src);
    }

    #[test]
    fn pad_rect_zero_fills() {
        let src = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]; // 3x2
        let p = PjrtDense::pad_rect(&src, 3, 2, 4, 4);
        assert_eq!(p.len(), 16);
        assert_eq!(p[0..3], [1.0, 2.0, 3.0]);
        assert_eq!(p[3], 0.0);
        assert_eq!(p[4..7], [4.0, 5.0, 6.0]);
        assert_eq!(&p[8..], &[0.0; 8]);
    }

    #[test]
    fn load_missing_dir_errors() {
        assert!(PjrtDense::load("/nonexistent/artifacts").is_err());
    }
}
