//! Structural-analysis scenario: a 3D finite-element-style problem
//! (apache2/boneS10 analogues) solved for multiple load cases, showing
//! the factor-once / solve-many workflow plus ordering impact.
//!
//! ```text
//! cargo run --release --example structural_grid
//! ```

use sparselu::ordering::OrderingMethod;
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual};

fn main() {
    // apache2-like 3D stiffness pattern
    let a = gen::grid3d_laplacian(16, 16, 14);
    let n = a.n_rows();
    println!("3D structural grid: n={n}, nnz={}", a.nnz());

    // ordering choice matters: compare fill under natural / RCM / min-degree
    println!("\nordering comparison (symbolic only):");
    for ord in [OrderingMethod::Natural, OrderingMethod::Rcm, OrderingMethod::MinDegree] {
        let perm = sparselu::ordering::order(&a, ord);
        let pa = a.permute_sym(perm.as_slice());
        let sym = sparselu::symbolic::analyze(&pa);
        println!(
            "  {ord:?}: nnz(L+U) = {} (fill {:.1}x), flops {:.2e}",
            sym.nnz_ldu(),
            sym.fill_ratio(&a),
            sym.flops()
        );
    }

    // factor once with the best ordering, solve many load cases
    let mut solver = Solver::new(SolveOptions::ours(2));
    let f = solver.factorize(&a).expect("factorize");
    println!(
        "\nfactored: {} blocks, numeric {:.3}s",
        f.report.num_blocks, f.report.numeric_seconds
    );

    let load_cases = 8;
    let t0 = std::time::Instant::now();
    let mut worst: f64 = 0.0;
    for c in 0..load_cases {
        // unit load at a moving face node + distributed load
        let mut b = vec![0.1; n];
        b[(c * 37) % n] = 100.0;
        let x = f.solve(&b);
        worst = worst.max(residual(&a, &x, &b));
    }
    println!(
        "{load_cases} load cases solved in {:.3}s total, worst residual {worst:.2e}",
        t0.elapsed().as_secs_f64()
    );
    assert!(worst < 1e-9);
    println!("structural_grid OK");
}
