//! End-to-end driver (DESIGN.md §validation): run the full pipeline —
//! generate → reorder → symbolic → irregular-block → schedule on 4
//! simulated GPUs → numeric factorize → triangular solve — on the two
//! matrices the paper singles out in §5.3 (ASIC_680k: extreme win;
//! ecology1: parity), and report the paper's headline metric (numeric-
//! factorization speedup of irregular over regular blocking) plus
//! correctness residuals. Recorded in EXPERIMENTS.md.
//!
//! ```text
//! cargo run --release --example end_to_end
//! ```

use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual, Csc};

struct Case {
    name: &'static str,
    matrix: Csc,
    /// Paper's 4-GPU speedup of irregular over PanguLU (Table 5).
    paper_speedup: f64,
}

fn main() {
    let cases = vec![
        Case {
            name: "ASIC_680k-like (BBD, 98% nnz in border)",
            matrix: gen::circuit_bbd(gen::CircuitParams {
                n: 6800,
                border_frac: 0.05,
                border_density: 0.35,
                interior_deg: 2,
                seed: 0x680F,
            }),
            paper_speedup: 4.08,
        },
        Case {
            name: "ecology1-like (2D grid, linear distribution)",
            matrix: gen::grid2d_laplacian(100, 100),
            paper_speedup: 0.98,
        },
    ];

    println!("end-to-end: 4 simulated GPUs, irregular (ours) vs regular (PanguLU)");
    println!("====================================================================");
    for case in &cases {
        let n = case.matrix.n_rows();
        println!("\n{} — n={}, nnz={}", case.name, n, case.matrix.nnz());
        let b: Vec<f64> = (0..n).map(|i| ((i * 31) % 17) as f64 - 8.0).collect();

        let mut results = Vec::new();
        for (label, opts) in [
            ("ours   ", SolveOptions::ours(4)),
            ("pangulu", SolveOptions::pangulu(4)),
        ] {
            let mut solver = Solver::new(opts);
            let f = solver.factorize(&case.matrix).expect("factorize");
            let x = f.solve(&b);
            let res = residual(&case.matrix, &x, &b);
            assert!(res < 1e-8, "{label}: residual {res}");
            let r = &f.report;
            println!(
                "  {label}: numeric {:.3}s | modeled A100 makespan {:.4}s | {} blocks | \
                 block-nnz CV {:.2} | residual {res:.1e}",
                r.numeric_seconds,
                r.modeled_makespan,
                r.num_blocks,
                r.balance.block_summary.cv(),
            );
            results.push((r.numeric_seconds, r.modeled_makespan));
        }
        let measured = results[1].0 / results[0].0;
        let modeled = results[1].1 / results[0].1;
        println!(
            "  speedup irregular/regular: measured {measured:.2}x | modeled {modeled:.2}x | paper {:.2}x",
            case.paper_speedup
        );
    }
    println!("\nend_to_end OK");
}
