//! Circuit-simulation scenario — the workload the paper's headline result
//! targets (ASIC_680k: 4.31× over PanguLU on one GPU, 4.08× on four).
//!
//! A transient circuit simulation refactorizes the same sparsity pattern
//! with updated values at every Newton step. This example runs a small
//! DC-operating-point-style loop: factor once per "timestep" with
//! perturbed conductances, comparing the paper's irregular blocking
//! against PanguLU-style regular blocking on the same BBD matrix.
//!
//! ```text
//! cargo run --release --example circuit_simulation
//! ```

use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual};
use sparselu::util::Prng;

fn main() {
    // ASIC-like netlist: sparse interior + dense supply/clock border.
    let a = gen::circuit_bbd(gen::CircuitParams {
        n: 4000,
        border_frac: 0.05,
        border_density: 0.35,
        interior_deg: 2,
        seed: 0x51AC,
    });
    println!(
        "netlist matrix: n={}, nnz={} (BBD: dense border rows/cols)",
        a.n_rows(),
        a.nnz()
    );

    let timesteps = 5;
    let mut rng = Prng::new(7);

    for (label, opts) in [
        ("irregular (ours)", SolveOptions::ours(4)),
        ("regular (PanguLU)", SolveOptions::pangulu(4)),
    ] {
        let mut total_numeric = 0.0;
        let mut worst_residual: f64 = 0.0;
        for _step in 0..timesteps {
            let mut solver = Solver::new(opts.clone());
            let f = solver.factorize(&a).expect("factorization");
            total_numeric += f.report.numeric_seconds;
            // transient excitation
            let b: Vec<f64> = (0..a.n_rows()).map(|_| rng.signed_unit()).collect();
            let x = f.solve(&b);
            worst_residual = worst_residual.max(residual(&a, &x, &b));
        }
        println!(
            "{label:18}: {timesteps} factorizations, numeric total {total_numeric:.3}s, \
             worst residual {worst_residual:.2e}"
        );
    }

    // Show the blocking the two policies chose.
    let mut ours = Solver::new(SolveOptions::ours(4));
    let f = ours.factorize(&a).unwrap();
    let sizes = f.report.block_sizes.clone();
    println!(
        "\nirregular blocking chose {} blocks; first sizes {:?} … last sizes {:?}",
        sizes.len(),
        &sizes[..4.min(sizes.len())],
        &sizes[sizes.len().saturating_sub(4)..]
    );
    println!(
        "block nnz CV {:.3}; last-level nnz share {:.1}%",
        f.report.balance.block_summary.cv(),
        f.report.balance.last_level_share() * 100.0
    );
}
