//! Circuit-simulation scenario — the workload the paper's headline result
//! targets (ASIC_680k: 4.31× over PanguLU on one GPU, 4.08× on four),
//! now driven through the `session` subsystem.
//!
//! A transient circuit simulation refactorizes the same sparsity pattern
//! with updated conductances at every Newton step. The old version of
//! this example re-ran the *entire* pipeline (ordering, symbolic,
//! blocking, DAG construction) per step; with a `SolverSession` the
//! structure-aware analysis runs **once** per netlist and every step pays
//! only the numeric phase.
//!
//! ```text
//! cargo run --release --example circuit_simulation
//! ```

use sparselu::session::{ChangeSet, FactorPlan, SolverSession};
use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual, Csc};
use sparselu::util::{timer::timed, Prng};
use std::sync::Arc;

/// Perturb the conductance values (same pattern) like a Newton update.
fn newton_values(a: &Csc, rng: &mut Prng) -> Vec<f64> {
    a.values.iter().map(|v| v * (1.0 + 0.02 * rng.signed_unit())).collect()
}

/// The matrix with the step's values (for residual checks).
fn with_values(a: &Csc, values: &[f64]) -> Csc {
    Csc::from_parts_unchecked(
        a.n_rows(),
        a.n_cols(),
        a.col_ptr.clone(),
        a.row_idx.clone(),
        values.to_vec(),
    )
}

fn main() {
    // ASIC-like netlist: sparse interior + dense supply/clock border.
    let a = gen::circuit_bbd(gen::CircuitParams {
        n: 4000,
        border_frac: 0.05,
        border_density: 0.35,
        interior_deg: 2,
        seed: 0x51AC,
    });
    println!(
        "netlist matrix: n={}, nnz={} (BBD: dense border rows/cols)",
        a.n_rows(),
        a.nnz()
    );

    let timesteps = 8;
    let opts = SolveOptions::ours(4);

    // --- cold baseline: full pipeline per step (the pre-session path) ---
    let (_, cold_step) = timed(|| {
        let mut solver = Solver::new(opts.clone());
        solver.factorize(&a).expect("cold factorization")
    });

    // --- session path: one plan, numeric-only steps ---
    let (plan, plan_seconds) =
        timed(|| Arc::new(FactorPlan::build(&a, &opts).expect("plan build")));
    println!(
        "\nFactorPlan built once: {:.4}s (reorder {:.4}s, symbolic {:.4}s, \
         preprocess {:.4}s, scatter-map+sim {:.4}s)",
        plan.report.total_seconds(),
        plan.report.reorder_seconds,
        plan.report.symbolic_seconds,
        plan.report.preprocess_seconds,
        plan.report.plan_extra_seconds,
    );

    let mut session = SolverSession::from_plan(plan.clone());
    let mut rng = Prng::new(7);
    let mut warm_total = 0.0;
    let mut worst_residual: f64 = 0.0;
    for step in 0..timesteps {
        let values = newton_values(&a, &mut rng);
        let rep = session.refactorize(&values).expect("refactorize");
        warm_total += rep.scatter_seconds + rep.numeric_seconds;

        // transient excitation, several sources solved in one batched sweep
        let rhs: Vec<Vec<f64>> = (0..3)
            .map(|_| (0..a.n_rows()).map(|_| rng.signed_unit()).collect())
            .collect();
        let xs = session.solve_many(&rhs);
        let astep = with_values(&a, &values);
        for (b, x) in rhs.iter().zip(&xs) {
            worst_residual = worst_residual.max(residual(&astep, x, b));
        }
        if step == 0 {
            println!(
                "first Newton step: scatter {:.5}s + numeric {:.4}s",
                rep.scatter_seconds, rep.numeric_seconds
            );
        }
    }

    let warm_step = warm_total / timesteps as f64;
    println!(
        "\n{} Newton steps through one session: {:.3}s total ({:.4}s/step), \
         worst residual {:.2e}",
        timesteps, warm_total, warm_step, worst_residual
    );
    println!("cold factorize (full pipeline) per step: {cold_step:.4}s");
    println!(
        "amortized speedup vs cold factorization: {:.2}x/step \
         (plan cost {:.4}s repaid after {:.1} steps)",
        cold_step / warm_step.max(1e-12),
        plan_seconds,
        plan_seconds / (cold_step - warm_step).max(1e-12),
    );
    assert!(
        Arc::ptr_eq(session.plan(), &plan),
        "plan constructed exactly once and reused for every step"
    );
    assert_eq!(session.refactor_count(), timesteps);

    // --- incremental path: device stamp updates between Newton steps ---
    // Once the iteration localizes (only one device still re-linearizing),
    // a step touches just that device's conductance entries. A ChangeSet
    // names them and `refactorize_partial` re-runs only the DAG tasks
    // reachable from the dirty blocks — bit-identical to a full
    // refactorize of the updated matrix.
    println!("\n--- incremental device-stamp updates ---");
    let stamp_steps = 8;
    let mut stamp_total = 0.0;
    let (mut last_exec, mut last_skip) = (0usize, 0usize);
    for step in 0..stamp_steps {
        // the device between nodes (40, 41): both diagonal conductances move
        let (n0, n1) = (40, 41);
        let g = 1.0e-3 * (1.0 + 0.1 * (step as f64 + 1.0));
        let stamp = ChangeSet::from_coords(
            &a,
            &[
                (n0, n0, session.current_values()[a.value_index(n0, n0).unwrap()] + g),
                (n1, n1, session.current_values()[a.value_index(n1, n1).unwrap()] + g),
            ],
        )
        .expect("device stamp lies inside the netlist pattern");
        let rep = session.refactorize_partial(&stamp).expect("partial refactorize");
        stamp_total += rep.scatter_seconds + rep.numeric_seconds;
        last_exec = rep.tasks_executed;
        last_skip = rep.tasks_skipped;
        if step == 0 {
            println!(
                "stamp touches {} block(s), closure re-runs {} block(s): \
                 {} of {} tasks executed",
                rep.blocks_dirty,
                rep.blocks_affected,
                rep.tasks_executed,
                rep.tasks_executed + rep.tasks_skipped,
            );
        }
    }
    let astamp = with_values(&a, session.current_values());
    let b_probe: Vec<f64> = (0..a.n_rows()).map(|i| (i % 5) as f64 - 2.0).collect();
    let x_probe = session.solve(&b_probe);
    println!(
        "{} stamp updates: {:.4}s total ({:.5}s/update, {} executed / {} skipped tasks), \
         residual {:.2e}, speedup vs full warm step {:.1}x",
        stamp_steps,
        stamp_total,
        stamp_total / stamp_steps as f64,
        last_exec,
        last_skip,
        residual(&astamp, &x_probe, &b_probe),
        warm_step / (stamp_total / stamp_steps as f64).max(1e-12),
    );
    assert_eq!(session.refactor_count(), timesteps + stamp_steps);
}
