//! Quickstart: build a matrix, factorize with the paper's irregular
//! blocking, solve, check the residual.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use sparselu::solver::{SolveOptions, Solver};
use sparselu::sparse::{gen, residual};

fn main() {
    // An ecology1-like 2D problem (linear nonzero distribution).
    let a = gen::grid2d_laplacian(80, 80);
    println!("matrix: 2D Laplacian, n={}, nnz={}", a.n_rows(), a.nnz());

    // The paper's configuration: min-degree ordering, irregular blocking
    // (Algorithm 3), sparse kernels with dense fallback.
    let mut solver = Solver::new(SolveOptions::ours(1));
    let f = solver.factorize(&a).expect("factorization");

    let r = &f.report;
    println!(
        "fill {:.1}x | {} blocks | {} tasks | numeric {:.3}s ({:.0}% of pipeline)",
        r.nnz_ldu as f64 / r.nnz_a as f64,
        r.num_blocks,
        r.tasks,
        r.numeric_seconds,
        r.numeric_share() * 100.0
    );

    // Solve A x = b and verify.
    let b: Vec<f64> = (0..a.n_rows()).map(|i| (i % 7) as f64 + 1.0).collect();
    let x = f.solve(&b);
    let res = residual(&a, &x, &b);
    println!("residual: {res:.2e}");
    assert!(res < 1e-10);
    println!("quickstart OK");
}
