//! Feature explorer — the paper's §4.2 diagnostic workflow: compute the
//! diagonal block-based pointer (Algorithm 2) for each nonzero-
//! distribution archetype and show how the curve exposes structure, then
//! run Algorithm 3 and print the blocking it derives.
//!
//! ```text
//! cargo run --release --example feature_explorer
//! ```

use sparselu::blocking::{irregular_blocking, DiagFeature, IrregularParams};
use sparselu::sparse::gen;
use sparselu::symbolic;
use sparselu::util::Summary;

fn sparkline(values: &[f64]) -> String {
    const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    values
        .iter()
        .map(|&v| BARS[((v * 7.0).round() as usize).min(7)])
        .collect()
}

fn main() {
    let cases: Vec<(&str, sparselu::sparse::Csc)> = vec![
        ("linear (tridiagonal, Fig 7a)", gen::tridiagonal(3000)),
        (
            "uniform (random, Fig 7b)",
            gen::uniform_random(1500, 0.01, 0xF1),
        ),
        (
            "local dense regions (Fig 8a)",
            gen::local_dense_blocks(3000, &[(700, 260), (2100, 320)], 2, 0xF2),
        ),
        (
            "dense rows/cols (Fig 8b)",
            gen::dense_rows_cols(3000, &[900, 2000], 2, 0xF3),
        ),
        (
            "BBD circuit (Fig 11 left)",
            gen::circuit_bbd(gen::CircuitParams { n: 3000, ..Default::default() }),
        ),
    ];

    for (name, a) in cases {
        // feature on the post-symbolic pattern, as the paper prescribes
        let sym = symbolic::analyze(&a);
        let ldu = sym.ldu_pattern(&a).unwrap();
        let curve = DiagFeature::from_csc(&ldu).curve();
        let sampled = curve.sample(48);
        println!("\n{name}");
        println!("  curve  {}", sparkline(&sampled));
        println!(
            "  quadratic score {:+.3} | max jump {:.4}",
            curve.quadratic_score(),
            curve.max_jump()
        );
        let blocking = irregular_blocking(&curve, &IrregularParams::default());
        let sizes: Vec<f64> = blocking.sizes().iter().map(|&s| s as f64).collect();
        let s = Summary::of(&sizes);
        println!(
            "  Algorithm 3 → {} blocks, sizes min/mean/max = {:.0}/{:.0}/{:.0} (cv {:.2})",
            blocking.num_blocks(),
            s.min,
            s.mean,
            s.max,
            s.cv()
        );
    }
}
